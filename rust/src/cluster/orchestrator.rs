//! The event-driven cluster engine: a global binary-heap event queue
//! over per-replica [`Node`]s, advancing a replica only when it has
//! work (DESIGN.md "Event-driven cluster engine").
//!
//! The lockstep reference engine ([`crate::cluster::Router`]) advances
//! every replica to every arrival — O(arrivals × replicas) `run_until`
//! calls, almost all of them no-ops on wide fleets. This engine keeps
//! one [`EventHeap`] ordered by the deterministic key
//! `(time, kind, replica, task)` and pops four event kinds:
//!
//!   * [`EventKind::Wake`] — a node's next-interesting-event time was
//!     reached: advance *that node* to the current routing boundary
//!     (one `run_until`, the same call lockstep would have made);
//!   * [`EventKind::Lifecycle`] — a replica joins, leaves, or crashes
//!     (elastic fleets, [`Orchestrator::with_lifecycle`]): apply the
//!     fleet change and evacuate the casualty;
//!   * [`EventKind::Boot`] — a boot-delayed autoscaler grow completes
//!     and the replica joins the fleet
//!     (`[cluster.autoscaler] boot_delay_s`);
//!   * [`EventKind::Heartbeat`] — a failure-detector tick
//!     (`[cluster.detector]`, DESIGN.md "Failure detection &
//!     recovery"): functioning replicas emit lag-delayed heartbeats,
//!     the suspicion machine runs, and timed-out corpses are confirmed
//!     dead and recovered;
//!   * [`EventKind::RescheduleBoundary`] — the final drain boundary at
//!     the common horizon;
//!   * [`EventKind::MigrationCheck`] — overload-triggered migration
//!     (DESIGN.md "Control-plane incrementality"): armed only when a
//!     replica's Eq. 7 headroom crosses the overload threshold, it runs
//!     the shared [`Controller`] migration passes just before the
//!     same-time arrival routes;
//!   * [`EventKind::Retry`] — re-dispatch one in-limbo task recovered
//!     at a confirmation (bounded attempts, exponential backoff);
//!   * [`EventKind::Arrival`] — route one task: decide, assign (plus
//!     health scoring and the autoscaler's observation when elastic).
//!
//! Exactly one `Arrival` and one `Lifecycle` event are in the heap at
//! a time (each stream pushes its next entry when the current one
//! pops), so the heap holds at most one wake per node plus a few
//! boundary events — O(events log replicas) total work. The effective
//! routing boundary every wake advances to is the *earlier* of the
//! next arrival and the next lifecycle event, so no node ever runs
//! past a crash instant. Arrivals are pulled one at a time from the
//! caller's iterator, so a seeded [`crate::workload::ArrivalStream`]
//! drives million-task traces in constant memory
//! ([`Orchestrator::run_stream`]).
//!
//! ## Epoch-batched parallel advancement
//!
//! With `[cluster] threads = N` / `--threads N` above 1, the engine
//! batches the heap into *epochs*: the maximal run of wake events
//! leading the heap — everything scheduled before the next
//! control-plane event — is popped at once, stale-filtered, and the
//! woken nodes advance to the boundary concurrently on scoped worker
//! threads ([`Orchestrator::run_epoch`]). Between two control-plane
//! events node advancement is cross-replica independent, so the merge
//! (wake refresh + parking, applied in replica-index order on the
//! orchestrator thread) reproduces the sequential engine bit-for-bit
//! at *any* thread count; `threads = 1` (the default) runs today's
//! exact sequential path. DESIGN.md "Parallel event engine" carries
//! the full determinism argument and the Send audit.
//!
//! ## Why this reproduces lockstep bit-for-bit
//!
//! The engine only ever calls `run_until` with *boundary times* — the
//! same arrival-time/horizon targets the lockstep loop uses — and it
//! skips exactly the calls that would have been no-ops: a replica with
//! no live, staged, or pending work neither delivers arrivals nor runs
//! engine steps under `run_until`, it only moves its clock, and every
//! routing-visible load signal is clock-independent. Wake events sort
//! *before* same-time `Arrival`/`RescheduleBoundary` events (the kind
//! rank), so every node with work due by a boundary is advanced to it
//! before the boundary's decision runs — the lockstep order.
//!
//! Migration is *edge-triggered*: the lockstep reference runs the (per
//! replica, mostly no-op) migration passes at every arrival boundary,
//! while this engine maintains a per-node overload shadow — refreshed
//! only where load can grow (an assignment, a migration, an
//! evacuation) — and arms a `MigrationCheck` at the in-flight
//! arrival's time only while some replica is overloaded. The check
//! sorts before the same-time `Arrival` (kind rank), so the passes
//! still run at exactly the boundaries where the lockstep pass would
//! have *acted* (its per-source gate is `alive ∧ overloaded`), and the
//! migrated-task set matches lockstep bit-for-bit; only the
//! pass/check counters differ — O(overload episodes) instead of
//! O(arrivals) — which is the relaxed part of the equivalence story
//! (`ClusterReport::{migration_passes, migration_checks}` are excluded
//! from the engine-pair comparison and asserted `event ≤ lockstep`
//! instead). One ordering note: health scores now fold in an arrival
//! boundary's lag *after* any same-time migration pass (the check pops
//! first), so a health+migration combination sees verdicts one
//! boundary staler than the old inline order did — no pinned
//! experiment enables both.
//!
//! ## Delayed failure detection
//!
//! With `[cluster.detector]` active, a crash stops being
//! oracle-visible. The Lifecycle crash handler *silences* the victim
//! instead of retiring it: the node freezes (wake cleared and never
//! re-armed — [`Orchestrator::refresh_wake`] early-returns for
//! silenced nodes, and stale heap wakes die on the mismatch filter),
//! the controller marks it `unresponsive` (migration withdrawals and
//! shrink picks need a *response*; sends do not), and the set of
//! global ids still queued there is snapshotted. The controller still
//! believes the replica alive, so dispatches keep landing in its
//! staged queue — *in limbo*. Heartbeat ticks then drive the
//! [`FailureDetector`]: suspected replicas leave the placement pool
//! (`Controller::placeable`), and when a silenced replica's heartbeat
//! age reaches the suspicion timeout it is confirmed: its pre-crash
//! queue re-places free (the byte-identical oracle requeue path), its
//! in-service tasks re-admit at the crash recompute price, and every
//! limbo task re-dispatches under bounded retry with exponential
//! backoff — exhausted tasks shed as `retry_exhausted`, and anything
//! still limboed when the horizon lands drains as `limbo_lost`. With
//! the detector inert (`suspicion_timeout = 0`) none of this machinery
//! exists at runtime and crashes take the PR 7 oracle path bit-for-bit
//! (pinned by `rust/tests/equivalence.rs`).
//!
//! The equivalence suite (`rust/tests/equivalence.rs`) pins all of
//! this: every cluster / hetero-fleet / memory cell must produce an
//! identical [`ClusterReport`] under both engines.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};

use anyhow::Result;

use crate::coordinator::task::{Task, TaskId};
use crate::engine::memory::MemoryConfig;
use crate::util::rng::Rng;
use crate::util::Micros;

use super::autoscaler::{Autoscaler, ScaleDecision};
use super::controller::Controller;
use super::detector::{FailureDetector, Verdict};
use super::fleet::AdmissionConfig;
use super::health::HealthTracker;
use super::lifecycle::{LifecycleAction, LifecycleConfig, LifecycleEvent};
use super::node::Node;
use super::replica::Replica;
use super::router::{ClusterReport, RoutingStrategy};

/// What a popped event asks the orchestrator to do. The discriminant
/// order is the heap tie-break rank at equal times — the documented
/// lifecycle ordering contract (DESIGN.md "Elastic fleets"): wakes
/// first (nodes reach the boundary before anything decides there),
/// then fleet changes (a crash at `t` is visible to every same-time
/// decision, and a boot joins before anything routes at `t`), then
/// heartbeat ticks (detection judges the settled fleet — a boot at `t`
/// is not a missed heartbeat), then the drain boundary (at the exact
/// horizon the drain wins, so a same-time confirmation's retries flush
/// as `limbo_lost` instead of racing it), then migration checks (the
/// passes run against the settled fleet, just ahead of the same-time
/// arrival), then retries (recovered tasks — always older than the
/// same-time arrival — re-dispatch first), then arrivals (routed
/// against the already-changed, already-rebalanced fleet).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A node's next-interesting-event time arrived: advance it.
    Wake,
    /// A replica joins, leaves, or crashes (elastic fleets).
    Lifecycle,
    /// A boot-delayed autoscaler grow completes: admit the replica.
    Boot,
    /// A failure-detector tick: emit heartbeats, run the suspicion
    /// machine, confirm and recover timed-out corpses.
    Heartbeat,
    /// The common drain horizon: advance everything with work, finish.
    RescheduleBoundary,
    /// Some replica crossed the overload threshold: run the migration
    /// passes before the same-time arrival routes (edge-triggered).
    MigrationCheck,
    /// Re-dispatch one recovered in-limbo task (bounded retry).
    Retry,
    /// Route the next workload task.
    Arrival,
}

/// One scheduled event. Ordering is the documented deterministic
/// contract: time, then kind rank, then replica id, then task id —
/// derived lexicographically from the field order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Event {
    /// Virtual time the event fires at.
    pub time: Micros,
    /// What to do (and the same-time rank; see [`EventKind`]).
    pub kind: EventKind,
    /// Node the event concerns (wake events; 0 otherwise).
    pub replica: usize,
    /// Task the event concerns (arrival events; 0 otherwise).
    pub task: TaskId,
}

/// A min-heap of [`Event`]s popping in `(time, kind, replica, task)`
/// order. Public so the property suite can drive it directly (the
/// never-pops-out-of-order invariant).
#[derive(Default)]
pub struct EventHeap {
    heap: BinaryHeap<Reverse<Event>>,
}

impl EventHeap {
    /// An empty heap.
    pub fn new() -> Self {
        EventHeap { heap: BinaryHeap::new() }
    }

    /// Schedule an event.
    pub fn push(&mut self, event: Event) {
        self.heap.push(Reverse(event));
    }

    /// Pop the least event under the deterministic key.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// The least event without removing it.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek().map(|Reverse(e)| e)
    }

    /// Number of scheduled events (stale wake entries included).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// The event-driven cluster engine: same construction surface and same
/// [`ClusterReport`] as [`crate::cluster::Router`], different time
/// advancement.
pub struct Orchestrator {
    nodes: Vec<Node>,
    ctl: Controller,
    /// Elastic-fleet configuration (inert default for static runs).
    lifecycle: LifecycleConfig,
    /// Builds the replica for fleet index `i` when one joins mid-run.
    factory: Option<Box<dyn FnMut(usize) -> Replica>>,
    autoscaler: Option<Autoscaler>,
    health: Option<HealthTracker>,
    /// Heartbeat-driven failure detection (`[cluster.detector]`);
    /// `None` keeps crashes oracle-visible (the PR 7 path).
    detector: Option<FailureDetector>,
    /// Ground truth the controller must not read: replicas that are
    /// physically dead but not yet confirmed by the detector. A
    /// silenced node is frozen (never advanced, never re-armed) and
    /// emits no heartbeats; the controller still believes it alive.
    silenced: Vec<bool>,
    /// Per-replica snapshot, taken at silence time, of the global ids
    /// then queued on the corpse — at confirmation this partitions its
    /// queue into pre-crash work (oracle-style free requeue) and tasks
    /// dispatched into the corpse afterwards (limbo, recovered via
    /// retry).
    limbo_base: Vec<HashSet<TaskId>>,
    /// Limbo tasks awaiting their scheduled retry (keyed by global id;
    /// each has exactly one `Retry` event in flight).
    limbo: HashMap<TaskId, Task>,
    /// Retry attempts consumed per recovered task — survives a task
    /// re-entering limbo on another corpse, so the budget is global.
    attempts: HashMap<TaskId, u32>,
    /// Per-node overload shadow (`alive ∧ overloaded`), maintained only
    /// while migration is enabled and refreshed only where load can
    /// grow — the edge-trigger that arms [`EventKind::MigrationCheck`]
    /// (DESIGN.md "Control-plane incrementality"). Stale-`true` entries
    /// cost one cheap re-check; stale-`false` is impossible by
    /// construction.
    overload: Vec<bool>,
    /// Number of `true` entries in `overload`.
    overload_count: usize,
    /// Worker threads for epoch-batched wake advancement (DESIGN.md
    /// "Parallel event engine"). 1 — the default — runs the exact
    /// sequential engine; N > 1 advances each epoch's nodes on up to N
    /// scoped worker threads, bit-exact with 1 by the merge-order
    /// argument on [`Orchestrator::run_epoch`].
    threads: usize,
    /// When set, every epoch's replica batch (in pop order) is
    /// recorded — the observability hook of the epoch property test.
    epoch_log: Option<Vec<Vec<usize>>>,
}

/// Reusable buffers for epoch collection, so the parallel engine's
/// steady state allocates only the per-epoch worker handles.
#[derive(Default)]
struct EpochScratch {
    /// Replicas to advance this epoch, in heap pop order.
    batch: Vec<usize>,
    /// Per-replica in-batch flags (sized to the fleet on demand), used
    /// to split the node slice into disjoint `&mut Node` work items.
    mask: Vec<bool>,
}

impl Orchestrator {
    /// Build an orchestrator over pre-constructed replicas (at least
    /// one), mirroring [`crate::cluster::Router::new`].
    pub fn new(strategy: RoutingStrategy, replicas: Vec<Replica>) -> Self {
        assert!(!replicas.is_empty(), "a cluster needs at least one replica");
        assert!(
            replicas.iter().enumerate().all(|(i, r)| r.id() == i),
            "replica ids must equal their fleet position"
        );
        let n = replicas.len();
        Orchestrator {
            nodes: replicas.into_iter().map(Node::new).collect(),
            ctl: Controller::new(strategy),
            lifecycle: LifecycleConfig::default(),
            factory: None,
            autoscaler: None,
            health: None,
            detector: None,
            silenced: vec![false; n],
            limbo_base: vec![HashSet::new(); n],
            limbo: HashMap::new(),
            attempts: HashMap::new(),
            overload: vec![false; n],
            overload_count: 0,
            threads: 1,
            epoch_log: None,
        }
    }

    /// Enable/configure per-class admission bounds.
    pub fn with_admission(mut self, admission: AdmissionConfig) -> Self {
        self.ctl.admission = admission;
        self
    }

    /// Enable or disable overload migration.
    pub fn with_migration(mut self, migration: bool) -> Self {
        self.ctl.migration = migration;
        self
    }

    /// Enable running-task KV-handoff migration, priced by `memory`.
    pub fn with_running_migration(mut self, enabled: bool, memory: MemoryConfig) -> Self {
        self.ctl.migrate_running = enabled;
        self.ctl.memory = memory;
        self
    }

    /// Fold rejected tasks into a counter instead of retaining them,
    /// so shedding stays O(1) memory on streaming traces (the
    /// per-task reject list would otherwise grow with the trace).
    /// `ClusterReport::rejected_folded` carries the count.
    pub fn with_fold_rejects(mut self, fold: bool) -> Self {
        self.ctl.fold_rejects = fold;
        self
    }

    /// Set the worker-thread count for epoch-batched wake advancement
    /// (`[cluster] threads` / `--threads`; clamped to at least 1).
    /// Every thread count produces the bit-identical [`ClusterReport`]
    /// — the knob only buys wall time on wide fleets, where the nodes
    /// woken between two control-plane events advance concurrently.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Attach the elastic-fleet machinery: the lifecycle event stream
    /// (explicit + seeded churn), the autoscaler and health tracker
    /// when their configs enable them, and a `factory` that builds the
    /// replica for each fleet index that joins mid-run (it must mint
    /// replicas with `id == index`, calibrated like the initial fleet).
    ///
    /// The liveness/health masks are initialized even when every
    /// sub-feature is disabled, so an all-disabled elastic run
    /// exercises the elastic decision paths for real — and must still
    /// be bit-exact with a static-fleet run (pinned by
    /// `rust/tests/equivalence.rs`).
    pub fn with_lifecycle(
        mut self,
        cfg: LifecycleConfig,
        factory: Box<dyn FnMut(usize) -> Replica>,
    ) -> Self {
        let n = self.nodes.len();
        self.ctl.alive = vec![true; n];
        self.ctl.degraded = vec![false; n];
        self.ctl.suspected = vec![false; n];
        self.ctl.unresponsive = vec![false; n];
        if cfg.detector.active() {
            self.detector = Some(FailureDetector::new(cfg.detector.clone(), n));
        }
        if cfg.autoscaler.enabled {
            self.autoscaler = Some(Autoscaler::new(
                cfg.autoscaler.clone(),
                cfg.min_replicas,
                cfg.max_replicas,
            ));
        }
        if cfg.health.enabled {
            self.health = Some(HealthTracker::new(cfg.health.clone(), n));
        }
        self.lifecycle = cfg;
        self.factory = Some(factory);
        self
    }

    /// Number of replicas in the fleet.
    pub fn replica_count(&self) -> usize {
        self.nodes.len()
    }

    /// Admit a factory-built replica at the next fleet index, its
    /// clock synced to `now`, alive and healthy.
    fn admit_replica(&mut self, now: Micros) -> usize {
        let factory = self
            .factory
            .as_mut()
            .expect("elastic runs carry a replica factory");
        let id = self.nodes.len();
        let replica = factory(id);
        assert_eq!(replica.id(), id, "factory must mint the next fleet index");
        let mut node = Node::new(replica);
        node.sync_clock(now);
        self.nodes.push(node);
        self.ctl.alive.push(true);
        self.ctl.degraded.push(false);
        self.ctl.suspected.push(false);
        self.ctl.unresponsive.push(false);
        self.silenced.push(false);
        self.limbo_base.push(HashSet::new());
        self.overload.push(false); // a joiner is idle
        if let Some(h) = &mut self.health {
            h.ensure(id + 1);
        }
        if let Some(d) = &mut self.detector {
            d.ensure(id + 1, now);
        }
        id
    }

    /// Mark `target` dead and evacuate it (the caller bumps the
    /// matching counter). Dead first: every placement inside the
    /// evacuation then naturally excludes it.
    fn retire_replica(&mut self, target: usize, crash: bool) {
        self.ctl.alive[target] = false;
        self.ctl.evacuate(&mut self.nodes, target, crash);
        if self.overload[target] {
            // dead nodes never source a migration pass
            self.overload[target] = false;
            self.overload_count -= 1;
        }
    }

    /// A crash under delayed detection: the replica dies *without the
    /// controller noticing*. Freeze the node (its wake dies on the
    /// mismatch filter and [`Orchestrator::refresh_wake`] never
    /// re-arms it), mark it unresponsive (withdrawals and shrink picks
    /// fail physically), and snapshot its queued global ids so
    /// confirmation can tell pre-crash work from limbo. The controller
    /// keeps believing it alive — that belief is the detection gap.
    fn silence_replica(&mut self, target: usize) {
        self.silenced[target] = true;
        self.ctl.unresponsive[target] = true;
        self.limbo_base[target] = self.nodes[target].as_ref().pending_gids();
        self.nodes[target].clear_wake();
        if self.overload[target] {
            // a corpse raises no overload signal
            self.overload[target] = false;
            self.overload_count -= 1;
        }
    }

    /// The detector confirmed `target` dead at `now`: run the delayed
    /// half of the crash. Pre-crash queued work re-places free through
    /// the oracle requeue path; in-service work re-admits at the crash
    /// recompute price; tasks dispatched into the corpse during the
    /// detection gap (not in the silence-time snapshot) are *limbo* —
    /// recovered via bounded retry (first attempt immediately, then
    /// exponential backoff), or shed outright at `max_retries = 0`.
    fn confirm_dead(&mut self, target: usize, now: Micros, heap: &mut EventHeap) {
        self.ctl.detections += 1;
        self.ctl.alive[target] = false;
        self.ctl.suspected[target] = false; // dead outranks suspected
        let base = std::mem::take(&mut self.limbo_base[target]);
        let withdrawn = self.nodes[target].as_mut().withdraw_all();
        let (pre_crash, limbo): (Vec<Task>, Vec<Task>) =
            withdrawn.into_iter().partition(|t| base.contains(&t.id));
        self.ctl.requeue_evacuated(&mut self.nodes, target, pre_crash);
        self.ctl.evacuate_in_service(&mut self.nodes, target, true);
        let max_retries = self
            .detector
            .as_ref()
            .expect("confirmations only happen with a detector")
            .cfg()
            .max_retries;
        for task in limbo {
            self.ctl.limbo_recovered += 1;
            if max_retries == 0 {
                self.ctl.retry_exhausted += 1;
                self.ctl.reject(task);
                continue;
            }
            // the budget is global: a task re-limboed from an earlier
            // corpse keeps the attempts it already burned
            self.attempts.entry(task.id).or_insert(0);
            heap.push(Event { time: now, kind: EventKind::Retry, replica: 0, task: task.id });
            self.limbo.insert(task.id, task);
        }
    }

    /// Re-evaluate one node's overload-shadow entry. Only called while
    /// migration is enabled (the shadow is inert otherwise). A
    /// silenced node never reads overloaded — a corpse sends no
    /// signals, so its frozen pre-crash load must not arm checks.
    fn refresh_overload(&mut self, idx: usize) {
        let over = self.ctl.is_alive(idx)
            && !self.silenced[idx]
            && self.nodes[idx].as_ref().overloaded();
        if self.overload[idx] != over {
            self.overload[idx] = over;
            if over {
                self.overload_count += 1;
            } else {
                self.overload_count -= 1;
            }
        }
    }

    /// Re-evaluate the whole shadow — used after fleet-wide load
    /// movement (a migration pass, an evacuation, a lifecycle event)
    /// and inside the check handler to drop stale-`true` entries.
    fn refresh_overload_all(&mut self) {
        for i in 0..self.nodes.len() {
            self.refresh_overload(i);
        }
    }

    /// Arm a [`EventKind::MigrationCheck`] at the in-flight arrival's
    /// boundary when migration is on and the shadow reports overload —
    /// at most one per boundary (`armed_at` dedups), never at the
    /// drain horizon (lockstep runs no pass there either).
    fn arm_migration_check(
        &self,
        heap: &mut EventHeap,
        armed_at: &mut Option<Micros>,
        boundary: Micros,
        has_arrival: bool,
    ) {
        if !self.ctl.migration
            || self.overload_count == 0
            || !has_arrival
            || *armed_at == Some(boundary)
        {
            return;
        }
        *armed_at = Some(boundary);
        heap.push(Event {
            time: boundary,
            kind: EventKind::MigrationCheck,
            replica: 0,
            task: 0,
        });
    }

    /// Apply one lifecycle event at `now`. Events that would push the
    /// alive count outside the configured fleet bounds — or that target
    /// an already-dead replica — are skipped (not clamped), consuming
    /// no randomness.
    fn apply_lifecycle(&mut self, e: LifecycleEvent, now: Micros, target_rng: &mut Rng) {
        let alive = self.ctl.alive_count(self.nodes.len());
        match e.action {
            LifecycleAction::Join => {
                if alive >= self.lifecycle.max_replicas {
                    return;
                }
                self.admit_replica(now);
                self.ctl.joins += 1;
            }
            LifecycleAction::Leave | LifecycleAction::Crash => {
                // exits are bounded (and victims picked) on the
                // *functioning* fleet — alive and not silenced. With
                // the detector off nothing is ever silenced, so this
                // is exactly the old alive-count bound; with it on,
                // an undetected corpse can neither die twice nor keep
                // the bound from protecting the last live replica.
                let functioning = (0..self.nodes.len())
                    .filter(|&i| self.ctl.is_alive(i) && !self.silenced[i])
                    .count();
                if functioning <= self.lifecycle.min_replicas {
                    return;
                }
                let target = match e.target {
                    Some(t) => {
                        if t >= self.nodes.len()
                            || !self.ctl.is_alive(t)
                            || self.silenced[t]
                        {
                            return;
                        }
                        t
                    }
                    None => {
                        let alive_ids: Vec<usize> = (0..self.nodes.len())
                            .filter(|&i| self.ctl.is_alive(i) && !self.silenced[i])
                            .collect();
                        alive_ids[target_rng.range_usize(0, alive_ids.len() - 1)]
                    }
                };
                let crash = e.action == LifecycleAction::Crash;
                if crash {
                    self.ctl.crashes += 1;
                } else {
                    self.ctl.leaves += 1;
                }
                if crash && self.detector.is_some() {
                    // delayed detection: the fleet does not know yet
                    self.silence_replica(target);
                } else {
                    self.retire_replica(target, crash);
                }
            }
        }
    }

    /// Recompute a node's wake time after its workload changed
    /// (assignment or migration) and reschedule it in the heap. Stale
    /// heap entries are invalidated by the wake-time mismatch on pop.
    /// Silenced nodes are frozen: dispatches may still stage work on
    /// them (that is the limbo), but nothing must ever advance them.
    fn refresh_wake(&mut self, idx: usize, heap: &mut EventHeap) {
        if self.silenced[idx] {
            return;
        }
        let node = &mut self.nodes[idx];
        let next = node.next_event_time();
        if node.wake() == next {
            return; // already scheduled at the right time
        }
        match next {
            Some(t) => {
                node.set_wake(t);
                heap.push(Event { time: t, kind: EventKind::Wake, replica: idx, task: 0 });
            }
            None => node.clear_wake(),
        }
    }

    /// Pop one complete *epoch* — the maximal run of [`EventKind::Wake`]
    /// events leading the heap, i.e. everything scheduled before the
    /// next control-plane event (arrival, lifecycle, boot, migration
    /// check, or the drain boundary: anything that reads cross-replica
    /// state) — and advance the woken nodes to `next_boundary` on up to
    /// `self.threads` scoped worker threads. `first` is the wake the
    /// caller already popped.
    ///
    /// Why any thread count is bit-exact with the sequential path:
    ///
    ///   * After the stale filter each replica appears **at most once**
    ///     per epoch: a valid wake consumes `Node::wake`, so a second
    ///     heap entry for the same node cannot match it (pinned by the
    ///     epoch property test in `rust/tests/property_invariants.rs`).
    ///   * Advancement is **cross-node independent**: `Node::advance_to`
    ///     touches only that node's replica — server, policy, engine
    ///     and RNG are all per-replica — never the controller or a
    ///     peer, so per-node results cannot depend on worker schedule.
    ///     Workers observe nothing else (see
    ///     [`Controller::mask_snapshot`] for the read-only mask
    ///     contract); every controller *write* stays between epochs on
    ///     the orchestrator thread.
    ///   * Every observable merge effect — wake refreshes, parking —
    ///     is applied after the workers join, on this thread, in
    ///     **replica-index order**. Heap content is unobservable except
    ///     through pop order (deterministic by the event key), and the
    ///     parked set is drained order-insensitively, so the merge
    ///     fixes all visible state.
    ///
    /// A node that is busy exactly *at* the boundary after advancing is
    /// parked directly instead of re-pushing a same-time wake the
    /// sequential loop would immediately pop and park — same end state
    /// (wake consumed, node parked), one less heap round-trip.
    ///
    /// Worker errors are collected per node and the one whose replica
    /// pops first in the epoch is propagated, matching the sequential
    /// path's first-failure semantics.
    fn run_epoch(
        &mut self,
        first: Event,
        heap: &mut EventHeap,
        parked: &mut Vec<usize>,
        next_boundary: Micros,
        scratch: &mut EpochScratch,
    ) -> Result<()> {
        // collect: drain the leading wake run, stale-filtering and
        // parking exactly like the sequential arm
        scratch.batch.clear();
        let mut ev = Some(first);
        while let Some(e) = ev.take() {
            let node = &mut self.nodes[e.replica];
            if node.wake() == Some(e.time) {
                node.clear_wake();
                if node.advanced_to() == Some(next_boundary) {
                    parked.push(e.replica);
                } else {
                    scratch.batch.push(e.replica);
                }
            }
            if matches!(heap.peek(), Some(p) if p.kind == EventKind::Wake) {
                ev = heap.pop();
            }
        }
        if let Some(log) = &mut self.epoch_log {
            log.push(scratch.batch.clone());
        }
        let masks = self.ctl.mask_snapshot();
        debug_assert!(
            scratch.batch.iter().all(|&i| masks.is_alive(i)),
            "dead replicas must not wake inside an epoch"
        );
        debug_assert!(
            scratch.batch.iter().all(|&i| !self.silenced[i]),
            "silenced replicas are frozen and must not wake inside an epoch"
        );
        // advance: disjoint `&mut Node`s, chunked across the workers
        let workers = self.threads.min(scratch.batch.len());
        if workers <= 1 {
            for &i in &scratch.batch {
                self.nodes[i].advance_to(next_boundary)?;
            }
        } else {
            if scratch.mask.len() < self.nodes.len() {
                scratch.mask.resize(self.nodes.len(), false);
            }
            for &i in &scratch.batch {
                scratch.mask[i] = true;
            }
            let mask = &scratch.mask;
            let mut slots: Vec<(usize, &mut Node)> = self
                .nodes
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| mask[*i])
                .collect();
            let per = slots.len().div_ceil(workers);
            let mut failures: Vec<(usize, anyhow::Error)> = Vec::new();
            std::thread::scope(|s| {
                let handles: Vec<_> = slots
                    .chunks_mut(per)
                    .map(|chunk| {
                        s.spawn(move || {
                            for (idx, node) in chunk.iter_mut() {
                                if let Err(e) = node.advance_to(next_boundary) {
                                    return Some((*idx, e));
                                }
                            }
                            None
                        })
                    })
                    .collect();
                for handle in handles {
                    let outcome = match handle.join() {
                        Ok(o) => o,
                        Err(panic) => std::panic::resume_unwind(panic),
                    };
                    if let Some(failure) = outcome {
                        failures.push(failure);
                    }
                }
            });
            for &i in &scratch.batch {
                scratch.mask[i] = false;
            }
            if !failures.is_empty() {
                // deterministic propagation: the failure whose replica
                // pops first this epoch, as the sequential loop would
                let at = scratch
                    .batch
                    .iter()
                    .find_map(|r| failures.iter().position(|(i, _)| i == r))
                    .expect("worker failures reference batch replicas");
                return Err(failures.swap_remove(at).1);
            }
        }
        // merge: refresh wakes / park in replica-index order — the
        // deterministic order every run shares regardless of threads
        scratch.batch.sort_unstable();
        for &i in &scratch.batch {
            let node = &mut self.nodes[i];
            match node.next_event_time() {
                Some(t) if t > next_boundary => {
                    node.set_wake(t);
                    heap.push(Event { time: t, kind: EventKind::Wake, replica: i, task: 0 });
                }
                // busy exactly at the boundary: park directly (the
                // sequential loop re-pushes and immediately parks)
                Some(_) => parked.push(i),
                None => {}
            }
        }
        Ok(())
    }

    /// Route and serve an entire workload, then drain to `last_arrival
    /// + drain` — the same contract as [`crate::cluster::Router::run`],
    /// with identical output.
    pub fn run(self, workload: Vec<Task>, drain: Micros) -> Result<ClusterReport> {
        self.run_counted(workload, drain).map(|(report, _)| report)
    }

    /// [`Orchestrator::run`], additionally returning the per-node
    /// advancement counts (how many `run_until` calls each replica
    /// received) — the observability hook the idle-replica property
    /// test and the scale sweep's activity accounting use.
    pub fn run_counted(
        self,
        workload: Vec<Task>,
        drain: Micros,
    ) -> Result<(ClusterReport, Vec<u64>)> {
        assert!(
            workload.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "workload must be sorted by arrival"
        );
        let last_arrival = workload.last().map_or(0, |t| t.arrival);
        self.run_events(workload.into_iter(), Some(last_arrival + drain), drain)
            .map(|(report, counts, _)| (report, counts))
    }

    /// [`Orchestrator::run_counted`], additionally returning every
    /// epoch's replica batch in heap pop order — the observability
    /// hook of the epoch property tests
    /// (`rust/tests/property_invariants.rs`). Epochs only form on the
    /// parallel path, so the log is empty at `threads = 1`.
    pub fn run_counted_logged(
        mut self,
        workload: Vec<Task>,
        drain: Micros,
    ) -> Result<(ClusterReport, Vec<u64>, Vec<Vec<usize>>)> {
        self.epoch_log = Some(Vec::new());
        assert!(
            workload.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "workload must be sorted by arrival"
        );
        let last_arrival = workload.last().map_or(0, |t| t.arrival);
        self.run_events(workload.into_iter(), Some(last_arrival + drain), drain)
    }

    /// Route a pull-based arrival stream (e.g. a seeded
    /// [`crate::workload::ArrivalStream`]) without materializing the
    /// workload: tasks are pulled one at a time, so a million-task
    /// trace runs in memory bounded by the fleet's in-flight work, not
    /// the trace length. The drain horizon is `last pulled arrival +
    /// drain`, discovered when the stream ends. Streaming runs use
    /// static fleets (the lifecycle schedule needs the horizon up
    /// front); pair with [`Orchestrator::with_fold_rejects`] to keep
    /// shedding O(1) memory too.
    pub fn run_stream<I>(self, arrivals: I, drain: Micros) -> Result<(ClusterReport, Vec<u64>)>
    where
        I: IntoIterator<Item = Task>,
    {
        assert!(
            self.factory.is_none(),
            "streaming runs use static fleets (no lifecycle schedule)"
        );
        self.run_events(arrivals.into_iter(), None, drain)
            .map(|(report, counts, _)| (report, counts))
    }

    /// The event loop shared by [`Orchestrator::run_counted`] (horizon
    /// known up front, lifecycle schedulable) and
    /// [`Orchestrator::run_stream`] (horizon discovered at stream end).
    fn run_events<I>(
        mut self,
        mut arrivals: I,
        lifecycle_horizon: Option<Micros>,
        drain: Micros,
    ) -> Result<(ClusterReport, Vec<u64>, Vec<Vec<usize>>)>
    where
        I: Iterator<Item = Task>,
    {
        // refined to `last pulled arrival + drain` when the stream
        // ends; until then only boundary bookkeeping reads it
        let mut horizon: Micros = drain;
        let mut last_seen: Micros = 0;
        let boot_delay = self.lifecycle.autoscaler.boot_delay;
        let mut pending_boots: std::collections::VecDeque<Micros> =
            std::collections::VecDeque::new();
        // dedup flag: at most one MigrationCheck per arrival boundary
        let mut migration_check_at: Option<Micros> = None;
        let mut heap = EventHeap::new();
        // nodes that reached the current boundary and whose recomputed
        // wake is *at* the boundary (still busy there): re-armed after
        // the boundary advances, so a busy node cannot wake-loop
        let mut parked: Vec<usize> = Vec::new();
        // reusable epoch buffers (parallel path only; threads > 1)
        let mut epoch = EpochScratch::default();
        // the single in-flight arrival (its heap event carries the id)
        let mut next_arrival: Option<Task> = None;
        // the lifecycle stream mirrors the arrival stream: one event in
        // the heap at a time, the next pushed when it pops (streaming
        // runs have no lifecycle schedule — asserted by `run_stream`)
        let mut lifecycle_events = match lifecycle_horizon {
            Some(h) => self.lifecycle.schedule(h),
            None => Vec::new(),
        }
        .into_iter();
        let mut target_rng = self.lifecycle.target_rng();
        let mut next_lifecycle = lifecycle_events.next();
        if let Some(e) = next_lifecycle {
            heap.push(Event { time: e.time, kind: EventKind::Lifecycle, replica: 0, task: 0 });
        }
        // the heartbeat stream mirrors the lifecycle stream: one tick
        // in the heap at a time, the next pushed when it pops, ticks
        // strictly before the horizon (only with an active detector —
        // an inert one schedules nothing, the bit-exactness gate)
        let hb_interval = self.detector.as_ref().map(|d| d.cfg().heartbeat_interval);
        let mut next_heartbeat: Option<Micros> = None;
        if let (Some(iv), Some(h)) = (hb_interval, lifecycle_horizon) {
            if iv < h {
                next_heartbeat = Some(iv);
                heap.push(Event { time: iv, kind: EventKind::Heartbeat, replica: 0, task: 0 });
            }
        }
        // time of the next Arrival event, or the horizon once the
        // workload is exhausted
        let mut arrival_boundary = match arrivals.next() {
            Some(t) => {
                let at = t.arrival;
                last_seen = at;
                heap.push(Event { time: at, kind: EventKind::Arrival, replica: 0, task: t.id });
                next_arrival = Some(t);
                at
            }
            None => {
                horizon = last_seen + drain;
                heap.push(Event {
                    time: horizon,
                    kind: EventKind::RescheduleBoundary,
                    replica: 0,
                    task: 0,
                });
                horizon
            }
        };
        // the effective boundary every wake advances its node to: the
        // next arrival, the next fleet change, or the next heartbeat
        // tick, whichever is first — a node must never run past a crash
        // instant, and a confirmation's evacuation must not land on
        // nodes already advanced past the tick (with the detector off
        // the heartbeat term is always `None`: the boundary is
        // byte-identical to the pre-detector engine)
        let eff = |arrival: Micros, lc: &Option<LifecycleEvent>, hb: &Option<Micros>| {
            let b = lc.map_or(arrival, |e| arrival.min(e.time));
            hb.map_or(b, |t| b.min(t))
        };
        let mut next_boundary = eff(arrival_boundary, &next_lifecycle, &next_heartbeat);

        loop {
            let ev = heap
                .pop()
                .expect("the boundary-event chain keeps the heap non-empty");
            match ev.kind {
                EventKind::Wake => {
                    if self.threads <= 1 {
                        // the sequential path — today's exact engine,
                        // byte for byte (the parallel path below must
                        // reproduce it; DESIGN.md "Parallel event
                        // engine" carries the argument)
                        let node = &mut self.nodes[ev.replica];
                        if node.wake() != Some(ev.time) {
                            continue; // stale entry: the wake was refreshed
                        }
                        node.clear_wake();
                        if node.advanced_to() == Some(next_boundary) {
                            // already at the boundary and busy there —
                            // re-arm only after the boundary moves on
                            parked.push(ev.replica);
                            continue;
                        }
                        node.advance_to(next_boundary)?;
                        if let Some(t) = node.next_event_time() {
                            node.set_wake(t);
                            heap.push(Event {
                                time: t,
                                kind: EventKind::Wake,
                                replica: ev.replica,
                                task: 0,
                            });
                        }
                    } else {
                        self.run_epoch(ev, &mut heap, &mut parked, next_boundary, &mut epoch)?;
                    }
                }
                EventKind::Arrival => {
                    let task = next_arrival.take().expect("arrival event without its task");
                    debug_assert_eq!(task.id, ev.task);
                    if self.ctl.migration || self.autoscaler.is_some() {
                        // a migrated-in (or shrink-evacuated) task may
                        // carry an arrival time earlier than this
                        // boundary, so an *idle* destination must have
                        // its clock at the boundary — where lockstep
                        // left it — before the task lands, or it would
                        // be delivered (and prefilled) in the
                        // destination's past. Busy nodes are already
                        // here via their wakes; idle ones only need the
                        // clock moved (uncounted — no arrivals to
                        // deliver, no steps to run).
                        for node in &mut self.nodes {
                            if node.advanced_to() != Some(ev.time)
                                && node.next_event_time().is_none()
                            {
                                node.sync_clock(ev.time);
                            }
                        }
                    }
                    // health scores fold in this boundary's lag *before*
                    // anything decides, so migration targets and the
                    // routing pick see the same verdicts
                    if let Some(h) = &mut self.health {
                        for node in &self.nodes {
                            let r = node.as_ref();
                            if self.ctl.is_alive(r.id()) {
                                h.observe(r.id(), r.cycle_lag());
                            }
                        }
                        h.fill_mask(&mut self.ctl.degraded);
                    }
                    // migration passes no longer run inline here: a
                    // same-time MigrationCheck (armed only while some
                    // replica is overloaded) already popped and ran
                    // them — at every boundary where the lockstep pass
                    // would have acted, and only those
                    //
                    // the arriving task's per-cycle quota, read before
                    // the decision consumes the task (the headroom-mode
                    // autoscaler aggregates the fleet's Eq. 7 headroom
                    // for exactly this quota)
                    let quota = if self.lifecycle.autoscaler.grow_on_headroom {
                        task.slo.tokens_per_cycle()
                    } else {
                        0
                    };
                    let pick = self.ctl.decide(&self.nodes, &task);
                    match pick {
                        Some(p) => self.nodes[p].as_mut().assign(task),
                        None => self.ctl.reject(task),
                    }
                    // the autoscaler observes the decision's outcome
                    // (after the assign: the picked node no longer
                    // reads as idle, so it cannot be the shrink victim)
                    let mut scaled = false;
                    if self.autoscaler.is_some() {
                        let mut deficit = pick.is_none();
                        if !deficit && !self.ctl.admission.enabled {
                            // without admission nothing is ever shed;
                            // the deficit signal falls back to "every
                            // placeable replica is overrunning"
                            deficit = self
                                .nodes
                                .iter()
                                .map(AsRef::as_ref)
                                .filter(|r| self.ctl.placeable(r.id()))
                                .all(|r| r.overloaded());
                        }
                        if self.lifecycle.autoscaler.grow_on_headroom {
                            // headroom mode replaces the shed/overload
                            // deficit with the aggregate Eq. 7 signal:
                            // mean cycle headroom across the placeable
                            // fleet for this arrival's quota, measured
                            // after the assignment (the slack the next
                            // arrival will face). A shed still
                            // registers — it means zero placeable
                            // headroom, so the mean is zero too.
                            let mut sum: Micros = 0;
                            let mut n: Micros = 0;
                            for r in self.nodes.iter().map(AsRef::as_ref) {
                                if self.ctl.placeable(r.id()) {
                                    sum = sum.saturating_add(r.headroom(quota));
                                    n += 1;
                                }
                            }
                            // mean <= floor, compared multiplied out so
                            // integer division cannot round the signal
                            let floor = self.lifecycle.autoscaler.headroom_min;
                            deficit = n == 0 || sum <= floor.saturating_mul(n);
                        }
                        // shrink victim: an alive replica with no work
                        // at all — prefer degraded, then highest index.
                        // An unresponsive (silenced, undetected) corpse
                        // cannot acknowledge a shrink: skipped
                        let mut idle: Option<(bool, usize)> = None;
                        for (i, node) in self.nodes.iter().enumerate() {
                            if self.ctl.is_alive(i)
                                && !self.ctl.is_unresponsive(i)
                                && node.next_event_time().is_none()
                            {
                                let key = (self.ctl.is_degraded(i), i);
                                if idle.map_or(true, |b| key > b) {
                                    idle = Some(key);
                                }
                            }
                        }
                        // booting replicas count toward the observed
                        // fleet size so the autoscaler cannot overshoot
                        // max_replicas while grows are in flight (empty
                        // when boot_delay is 0 — the bit-exact default)
                        let alive =
                            self.ctl.alive_count(self.nodes.len()) + pending_boots.len();
                        let decision = self
                            .autoscaler
                            .as_mut()
                            .expect("checked is_some above")
                            .observe(ev.time, deficit, idle.map(|(_, i)| i), alive);
                        match decision {
                            ScaleDecision::Hold => {}
                            ScaleDecision::Grow => {
                                self.ctl.autoscale_grows += 1;
                                if boot_delay == 0 {
                                    self.admit_replica(ev.time);
                                    scaled = true;
                                } else {
                                    // deferred: the replica joins when
                                    // its Boot event fires
                                    let at = ev.time + boot_delay;
                                    pending_boots.push_back(at);
                                    heap.push(Event {
                                        time: at,
                                        kind: EventKind::Boot,
                                        replica: 0,
                                        task: 0,
                                    });
                                }
                            }
                            ScaleDecision::Shrink(idx) => {
                                self.ctl.autoscale_shrinks += 1;
                                self.retire_replica(idx, false);
                                scaled = true;
                            }
                        }
                    }
                    // move the boundary forward *before* re-arming
                    // wakes, so a wake at this same time advances
                    // instead of parking forever
                    arrival_boundary = match arrivals.next() {
                        Some(t) => {
                            let at = t.arrival;
                            debug_assert!(at >= last_seen, "arrivals must be time-ordered");
                            last_seen = at;
                            heap.push(Event {
                                time: at,
                                kind: EventKind::Arrival,
                                replica: 0,
                                task: t.id,
                            });
                            next_arrival = Some(t);
                            at
                        }
                        None => {
                            horizon = last_seen + drain;
                            heap.push(Event {
                                time: horizon,
                                kind: EventKind::RescheduleBoundary,
                                replica: 0,
                                task: 0,
                            });
                            horizon
                        }
                    };
                    next_boundary = eff(arrival_boundary, &next_lifecycle, &next_heartbeat);
                    if scaled {
                        // a scale action's evacuation may have moved
                        // work between any pair of nodes: re-arm the
                        // whole fleet
                        for i in 0..self.nodes.len() {
                            self.refresh_wake(i, &mut heap);
                        }
                        parked.clear();
                    } else {
                        // only the assigned node's workload changed —
                        // migration moves happen in the MigrationCheck
                        // handler, which re-arms the fleet itself
                        for i in std::mem::take(&mut parked) {
                            self.refresh_wake(i, &mut heap);
                        }
                        if let Some(p) = pick {
                            self.refresh_wake(p, &mut heap);
                        }
                    }
                    if self.ctl.migration {
                        // the only load that grew outside a scale
                        // action is the assigned node's
                        if scaled {
                            self.refresh_overload_all();
                        } else if let Some(p) = pick {
                            self.refresh_overload(p);
                        }
                        self.arm_migration_check(
                            &mut heap,
                            &mut migration_check_at,
                            arrival_boundary,
                            next_arrival.is_some(),
                        );
                    }
                }
                EventKind::Lifecycle => {
                    let e = next_lifecycle.take().expect("lifecycle event without its entry");
                    debug_assert_eq!(e.time, ev.time);
                    // same contract as the arrival boundary: evacuated
                    // tasks may land on idle peers, whose clocks must
                    // be at the event time first (uncounted moves)
                    for node in &mut self.nodes {
                        if node.advanced_to() != Some(ev.time)
                            && node.next_event_time().is_none()
                        {
                            node.sync_clock(ev.time);
                        }
                    }
                    self.apply_lifecycle(e, ev.time, &mut target_rng);
                    next_lifecycle = lifecycle_events.next();
                    if let Some(nl) = next_lifecycle {
                        heap.push(Event {
                            time: nl.time,
                            kind: EventKind::Lifecycle,
                            replica: 0,
                            task: 0,
                        });
                    }
                    next_boundary = eff(arrival_boundary, &next_lifecycle, &next_heartbeat);
                    // the fleet changed shape: re-arm everything (this
                    // also clears a dead node's stale wake and arms a
                    // joiner / every evacuation destination)
                    for i in 0..self.nodes.len() {
                        self.refresh_wake(i, &mut heap);
                    }
                    parked.clear();
                    if self.ctl.migration {
                        // evacuations may have overloaded destinations
                        self.refresh_overload_all();
                        self.arm_migration_check(
                            &mut heap,
                            &mut migration_check_at,
                            arrival_boundary,
                            next_arrival.is_some(),
                        );
                    }
                }
                EventKind::Boot => {
                    let due = pending_boots
                        .pop_front()
                        .expect("boot event without a pending boot");
                    debug_assert_eq!(due, ev.time);
                    // bounds re-check at boot time: explicit joins may
                    // have filled the fleet since the grow was decided
                    // (the grow stays counted; the boot is dropped)
                    if self.ctl.alive_count(self.nodes.len()) < self.lifecycle.max_replicas {
                        self.admit_replica(ev.time);
                    }
                    // the joiner is idle: no wake to arm, no load moved
                }
                EventKind::Heartbeat => {
                    debug_assert_eq!(Some(ev.time), next_heartbeat);
                    let mut det = self
                        .detector
                        .take()
                        .expect("heartbeat events only fire with a detector");
                    // functioning replicas emit this tick's heartbeats,
                    // delayed by their current Eq. 7 cycle lag — an
                    // overloaded replica heartbeats late (the organic
                    // false-suspicion source), a corpse not at all
                    for (i, node) in self.nodes.iter().enumerate() {
                        if self.ctl.is_alive(i) && !self.silenced[i] {
                            det.emit(i, ev.time, node.as_ref().cycle_lag());
                        }
                    }
                    // one suspicion step per believed-alive replica;
                    // confirmation (ground-truth gated) is deferred so
                    // every verdict this tick judges the same fleet
                    let mut confirmed: Vec<usize> = Vec::new();
                    for i in 0..self.nodes.len() {
                        if !self.ctl.is_alive(i) {
                            continue;
                        }
                        match det.tick(i, ev.time, self.silenced[i]) {
                            Verdict::None => {}
                            Verdict::Suspect => {
                                self.ctl.suspicions += 1;
                                self.ctl.suspected[i] = true;
                            }
                            Verdict::Unsuspect => {
                                self.ctl.false_suspicions += 1;
                                self.ctl.suspected[i] = false;
                            }
                            Verdict::Confirm => confirmed.push(i),
                        }
                    }
                    self.detector = Some(det);
                    if !confirmed.is_empty() {
                        // same contract as the lifecycle boundary:
                        // recovered tasks may land on idle peers, whose
                        // clocks must be at the tick first
                        for node in &mut self.nodes {
                            if node.advanced_to() != Some(ev.time)
                                && node.next_event_time().is_none()
                            {
                                node.sync_clock(ev.time);
                            }
                        }
                        for i in confirmed {
                            if self.ctl.alive_count(self.nodes.len()) <= 1 {
                                // never confirm the last believed-alive
                                // replica (unreachable while
                                // min_replicas >= 1; defer to next tick)
                                continue;
                            }
                            self.confirm_dead(i, ev.time, &mut heap);
                        }
                        // confirmation moved work (requeue, evacuation,
                        // retries): re-arm the fleet, like a lifecycle
                        for i in 0..self.nodes.len() {
                            self.refresh_wake(i, &mut heap);
                        }
                        parked.clear();
                        if self.ctl.migration {
                            self.refresh_overload_all();
                            self.arm_migration_check(
                                &mut heap,
                                &mut migration_check_at,
                                arrival_boundary,
                                next_arrival.is_some(),
                            );
                        }
                    }
                    next_heartbeat = None;
                    if let (Some(iv), Some(h)) = (hb_interval, lifecycle_horizon) {
                        let nt = ev.time + iv;
                        if nt < h {
                            next_heartbeat = Some(nt);
                            heap.push(Event {
                                time: nt,
                                kind: EventKind::Heartbeat,
                                replica: 0,
                                task: 0,
                            });
                        }
                    }
                    next_boundary = eff(arrival_boundary, &next_lifecycle, &next_heartbeat);
                }
                EventKind::MigrationCheck => {
                    migration_check_at = None;
                    self.ctl.migration_checks += 1;
                    // idle-clock sync first — the same contract as the
                    // arrival boundary (a migrated-in task may carry an
                    // arrival time earlier than this boundary, so an
                    // idle destination's clock must be here before the
                    // task lands), and the exact order the old inline
                    // passes ran under
                    for node in &mut self.nodes {
                        if node.advanced_to() != Some(ev.time)
                            && node.next_event_time().is_none()
                        {
                            node.sync_clock(ev.time);
                        }
                    }
                    // the shadow may be stale-true (service progress
                    // since arming drained the overload): re-check
                    // against live state before paying for a pass
                    self.refresh_overload_all();
                    if self.overload_count > 0 {
                        self.ctl.run_migrations(&mut self.nodes);
                        self.ctl.run_running_migrations(&mut self.nodes);
                        // migration may have moved work between any
                        // pair: refresh the shadow and re-arm the fleet
                        self.refresh_overload_all();
                        for i in 0..self.nodes.len() {
                            self.refresh_wake(i, &mut heap);
                        }
                        parked.clear();
                    }
                    // no re-arm here even if overload persists: the
                    // same-time arrival's handler arms the *next*
                    // boundary — the lockstep one-pass-per-boundary
                    // cadence, and no same-time check storm
                }
                EventKind::Retry => {
                    let task = self
                        .limbo
                        .remove(&ev.task)
                        .expect("retry event without its limbo task");
                    // idle-clock sync first — the retried task carries
                    // its original arrival time (same contract as the
                    // migration check)
                    for node in &mut self.nodes {
                        if node.advanced_to() != Some(ev.time)
                            && node.next_event_time().is_none()
                        {
                            node.sync_clock(ev.time);
                        }
                    }
                    let attempt = self.attempts.get(&ev.task).copied().unwrap_or(0) + 1;
                    self.attempts.insert(ev.task, attempt);
                    self.ctl.retries += 1;
                    // full admission: a retry competes like any fresh
                    // arrival — and may land on another not-yet-detected
                    // corpse, re-entering limbo there with its attempt
                    // count intact (the budget is global, not per-host)
                    match self.ctl.decide(&self.nodes, &task) {
                        Some(p) => {
                            self.nodes[p].as_mut().receive_migrated(task);
                            self.refresh_wake(p, &mut heap);
                            if self.ctl.migration {
                                self.refresh_overload(p);
                                self.arm_migration_check(
                                    &mut heap,
                                    &mut migration_check_at,
                                    arrival_boundary,
                                    next_arrival.is_some(),
                                );
                            }
                        }
                        None => {
                            let cfg = self
                                .detector
                                .as_ref()
                                .expect("retry events only fire with a detector")
                                .cfg();
                            // exponential backoff: attempt k + 1 fires
                            // retry_backoff << (k - 1) after attempt k
                            // fails (saturating — never wraps)
                            let factor = 1u64
                                .checked_shl(attempt.saturating_sub(1).min(63))
                                .unwrap_or(u64::MAX);
                            let next =
                                ev.time.saturating_add(cfg.retry_backoff.saturating_mul(factor));
                            let runway = lifecycle_horizon.map_or(false, |h| next < h);
                            if attempt < cfg.max_retries && runway {
                                heap.push(Event {
                                    time: next,
                                    kind: EventKind::Retry,
                                    replica: 0,
                                    task: ev.task,
                                });
                                self.limbo.insert(ev.task, task);
                            } else {
                                // budget or runway exhausted: shed,
                                // reported as a retry_exhausted loss
                                self.ctl.retry_exhausted += 1;
                                self.ctl.reject(task);
                            }
                        }
                    }
                }
                EventKind::RescheduleBoundary => {
                    debug_assert_eq!(ev.time, horizon);
                    // limbo tasks whose next retry fell past the horizon
                    // drain as shed losses (sorted by id: HashMap order
                    // is nondeterministic, reports must not be)
                    if !self.limbo.is_empty() {
                        let mut flushed: Vec<Task> =
                            self.limbo.drain().map(|(_, t)| t).collect();
                        flushed.sort_by_key(|t| t.id);
                        for task in flushed {
                            self.ctl.limbo_lost += 1;
                            self.ctl.reject(task);
                        }
                    }
                    // the drain boundary: same-time wakes already
                    // popped (kind rank), so every node with live work
                    // has been advanced to the horizon. Nodes that had
                    // work earlier but idled drain with a (counted)
                    // advancement, exactly like lockstep; nodes that
                    // never had work only sync their clock so reports
                    // end at the common horizon with zero advancements.
                    for i in 0..self.nodes.len() {
                        if self.silenced[i] {
                            // an unconfirmed corpse: frozen at its crash
                            // clock, its queue (pre-crash work and limbo
                            // dispatches alike) dies with it, and its
                            // in-service tasks stay in its report as
                            // unfinished — the drained assert below does
                            // not apply
                            let lost = self.nodes[i].as_mut().withdraw_all();
                            for task in lost {
                                self.ctl.limbo_lost += 1;
                                self.ctl.reject(task);
                            }
                            continue;
                        }
                        let node = &mut self.nodes[i];
                        if node.advanced_to() == Some(horizon) {
                            // drained by its own wake
                        } else if node.advancements() > 0 || node.wake().is_some() {
                            node.advance_to(horizon)?;
                        } else {
                            node.sync_clock(horizon);
                        }
                        let r = node.as_ref();
                        assert!(
                            r.pending() == 0,
                            "drain window too small: replica {} has {} undelivered arrivals",
                            r.id(),
                            r.pending()
                        );
                    }
                    break;
                }
            }
        }

        let counts: Vec<u64> = self.nodes.iter().map(Node::advancements).collect();
        self.ctl.autoscale_pending_boots = pending_boots.len() as u64;
        let epochs = self.epoch_log.take().unwrap_or_default();
        let replicas: Vec<Replica> =
            self.nodes.into_iter().map(Node::into_replica).collect();
        Ok((self.ctl.into_report(replicas), counts, epochs))
    }
}
