//! Replica lifecycle events: deterministic join/leave/crash streams for
//! elastic fleets (DESIGN.md "Elastic fleets").
//!
//! A lifecycle stream is fixed before the run starts: explicit events
//! (configured times, `[cluster.lifecycle]` / `--crash-at`) merged with
//! a seeded Poisson churn stream (`churn_rate` events/s, xoshiro256++
//! seeded by `seed`), sorted by time. The
//! [`Orchestrator`](super::Orchestrator) injects the schedule through
//! its event heap as [`EventKind::Lifecycle`](super::EventKind) events
//! — same heap, same deterministic `(time, kind, replica, task)`
//! tie-break — so reruns of one seed replay the identical churn
//! history, failures included.
//!
//! Semantics (enforced by the orchestrator):
//!   * **Crash** — the replica dies *with* its resident KV: queued
//!     tasks are withdrawn and re-placed for free, mid-generation tasks
//!     are re-admitted elsewhere with a full prefill *recompute* fee
//!     priced on the destination's own latency curve (the cache is
//!     gone; PR 4's restore machinery charges the fee on the clock).
//!   * **Leave** — a graceful exit: same evacuation, but surviving KV
//!     is handed off over the inter-replica link at the PR 4 handoff
//!     price.
//!   * **Join** — a fresh replica appends to the fleet (built by the
//!     caller-supplied factory), immediately placeable.
//!
//! Events that would push the alive count outside
//! [`min_replicas`, `max_replicas`] are skipped, not clamped — the
//! bound is on the *fleet*, and a skipped event consumes no randomness,
//! so determinism survives.

use crate::util::rng::Rng;
use crate::util::Micros;

/// What a lifecycle event does to the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifecycleAction {
    /// A fresh replica joins (factory-built, next fleet index).
    Join,
    /// A replica exits gracefully: its KV survives and is handed off.
    Leave,
    /// A replica dies losing its resident KV and its queue.
    Crash,
}

impl LifecycleAction {
    /// Display name used in reports and logs.
    pub fn label(&self) -> &'static str {
        match self {
            LifecycleAction::Join => "join",
            LifecycleAction::Leave => "leave",
            LifecycleAction::Crash => "crash",
        }
    }
}

/// One scheduled fleet change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LifecycleEvent {
    /// Virtual time the event fires at.
    pub time: Micros,
    /// What happens.
    pub action: LifecycleAction,
    /// Replica it targets (exits only). `None` picks uniformly among
    /// the alive replicas with the schedule's seeded RNG at fire time.
    pub target: Option<usize>,
}

/// Autoscaler signal shape (the fleet bounds live on
/// [`LifecycleConfig`] — they bound churn joins/exits too).
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalerConfig {
    /// Master switch (off by default: static fleets stay static).
    pub enabled: bool,
    /// Consecutive deficit observations (an arrival shed, or every
    /// alive healthy replica overloaded) before a grow fires.
    pub deficit_streak: u32,
    /// Consecutive idle observations (some alive replica fully idle,
    /// nothing shed) before a shrink fires.
    pub idle_streak: u32,
    /// Minimum time between scale actions (hysteresis).
    pub cooldown: Micros,
    /// Provisioning latency for a grow: the replica joins this long
    /// after the scale decision (a `Boot` event in the orchestrator
    /// heap). Booting replicas count toward the observed fleet size so
    /// grows in flight suppress further grows. 0 (the default) admits
    /// instantly — bit-exact with the pre-boot-delay engine.
    pub boot_delay: Micros,
    /// Grow on *aggregate Eq. 7 headroom* instead of the shed/overload
    /// deficit: the deficit observation becomes "mean cycle headroom
    /// across the placeable fleet (for the arriving task's quota) is at
    /// or below [`AutoscalerConfig::headroom_min`]". A shed arrival
    /// still registers (a shed means zero placeable headroom, so the
    /// mean is zero), but the fleet now also grows *before* it starts
    /// shedding, as slack drains toward the floor. Same streak and
    /// cooldown machinery; off by default (the PR 7 deficit signal).
    pub grow_on_headroom: bool,
    /// Mean-headroom floor in µs of Eq. 7 cycle slack, used only under
    /// [`AutoscalerConfig::grow_on_headroom`]. 0 fires only at full
    /// saturation (every placeable replica at zero headroom).
    pub headroom_min: Micros,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            enabled: false,
            deficit_streak: 2,
            idle_streak: 64,
            cooldown: 500_000, // 0.5 s
            boot_delay: 0,
            grow_on_headroom: false,
            headroom_min: 0,
        }
    }
}

/// Failure-detection shape (`[cluster.detector]`): heartbeat-driven
/// suspicion and confirmation, replacing PR 7's oracle crash
/// visibility with a detection *delay* during which the router keeps
/// dispatching into the dead replica (DESIGN.md "Failure detection &
/// recovery"). With `suspicion_timeout = 0` the subsystem is fully
/// inert and crashes stay oracle-visible — bit-exact with PR 7.
#[derive(Debug, Clone, PartialEq)]
pub struct DetectorConfig {
    /// Master switch (off by default: crashes stay oracle-visible).
    pub enabled: bool,
    /// Heartbeat tick period. Each tick, every functioning replica
    /// emits a heartbeat that arrives after its current Eq. 7 cycle
    /// lag, so overloaded replicas heartbeat late — the organic
    /// false-suspicion source.
    pub heartbeat_interval: Micros,
    /// Heartbeat age at which a silent replica is *confirmed* dead and
    /// recovered (evacuation + limbo re-dispatch). Ages past
    /// `heartbeat_interval` only *suspect* (placement exclusion,
    /// reversible). 0 disables detection entirely (the oracle path).
    pub suspicion_timeout: Micros,
    /// Retry budget per in-limbo task recovered at confirmation. 0
    /// sheds limbo tasks immediately at confirmation (the no-retry
    /// baseline the chaos sweep compares against).
    pub max_retries: u32,
    /// Base backoff before retry attempt `k` fires:
    /// `retry_backoff << (k - 1)` after the immediate first attempt.
    pub retry_backoff: Micros,
}

impl Default for DetectorConfig {
    fn default() -> Self {
        DetectorConfig {
            enabled: false,
            heartbeat_interval: 500_000,  // 0.5 s
            suspicion_timeout: 2_000_000, // 2 s
            max_retries: 3,
            retry_backoff: 500_000, // 0.5 s
        }
    }
}

impl DetectorConfig {
    /// True when detection actually runs: enabled with a nonzero
    /// timeout. `suspicion_timeout = 0` keeps the whole subsystem inert
    /// (no heartbeat events, oracle crash visibility) — the
    /// bit-exactness gate `rust/tests/equivalence.rs` pins.
    pub fn active(&self) -> bool {
        self.enabled && self.suspicion_timeout > 0
    }
}

/// Router health-scoring shape: an EWMA of per-replica boundary lag
/// (Eq. 7 cycle overrun at each routing boundary) plus a
/// recent-failure penalty while the replica is overrunning. See
/// [`HealthTracker`](super::HealthTracker) for the formula.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthConfig {
    /// Master switch (off by default).
    pub enabled: bool,
    /// EWMA weight of the newest lag sample (0 < alpha <= 1).
    pub alpha: f64,
    /// Score above which a replica is degraded (µs of cycle overrun).
    pub lag_threshold: Micros,
    /// Added to the lag sample while the replica is overloaded — a
    /// failure episode weighs more than its raw overrun.
    pub failure_penalty: Micros,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            enabled: false,
            alpha: 0.2,
            lag_threshold: 500_000,  // 0.5 s of cycle overrun
            failure_penalty: 250_000, // 0.25 s per overloaded observation
        }
    }
}

/// The elastic-fleet knob surface (`[cluster.lifecycle]` /
/// `[cluster.autoscaler]` / `[cluster.health]` / `[cluster.detector]`):
/// an explicit event schedule, a seeded churn stream, fleet-size
/// bounds, and the autoscaler/health/detector sub-configs.
#[derive(Debug, Clone, PartialEq)]
pub struct LifecycleConfig {
    /// Explicit events (configured times; merged with churn).
    pub events: Vec<LifecycleEvent>,
    /// Seeded Poisson churn rate in events/s (0 = off).
    pub churn_rate: f64,
    /// Seed for the churn stream and untargeted exit picks.
    pub seed: u64,
    /// The fleet never shrinks below this many alive replicas.
    pub min_replicas: usize,
    /// The fleet never grows past this many alive replicas.
    pub max_replicas: usize,
    /// Autoscaler signals/hysteresis.
    pub autoscaler: AutoscalerConfig,
    /// Health scoring shape.
    pub health: HealthConfig,
    /// Failure-detection shape (heartbeats, suspicion, retry).
    pub detector: DetectorConfig,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            events: Vec::new(),
            churn_rate: 0.0,
            seed: 1,
            min_replicas: 1,
            max_replicas: 64,
            autoscaler: AutoscalerConfig::default(),
            health: HealthConfig::default(),
            detector: DetectorConfig::default(),
        }
    }
}

impl LifecycleConfig {
    /// True when the run has lifecycle events to inject (explicit or
    /// churn).
    pub fn has_events(&self) -> bool {
        !self.events.is_empty() || self.churn_rate > 0.0
    }

    /// True when *any* elastic feature is on — the gate for attaching
    /// the elastic machinery to a run (and for refusing the lockstep
    /// engine, which cannot inject lifecycle events).
    pub fn any_enabled(&self) -> bool {
        self.has_events()
            || self.autoscaler.enabled
            || self.health.enabled
            || self.detector.enabled
    }

    /// Materialize the full schedule up to `horizon`: explicit events
    /// merged with the seeded churn stream, sorted by time (stable —
    /// explicit events win ties). Deterministic for a fixed config.
    pub fn schedule(&self, horizon: Micros) -> Vec<LifecycleEvent> {
        let mut out: Vec<LifecycleEvent> =
            self.events.iter().copied().filter(|e| e.time < horizon).collect();
        out.sort_by_key(|e| e.time);
        if self.churn_rate > 0.0 {
            let mut rng = Rng::new(self.seed);
            let mut t: Micros = 0;
            loop {
                let dt = rng.exponential(self.churn_rate); // seconds
                t = t.saturating_add((dt * 1e6) as Micros);
                if t >= horizon {
                    break;
                }
                // 40% crash / 40% join / 20% graceful leave: churn that
                // holds the expected fleet size roughly steady
                let u = rng.f64();
                let action = if u < 0.4 {
                    LifecycleAction::Crash
                } else if u < 0.8 {
                    LifecycleAction::Join
                } else {
                    LifecycleAction::Leave
                };
                out.push(LifecycleEvent { time: t, action, target: None });
            }
            out.sort_by_key(|e| e.time);
        }
        out
    }

    /// The RNG stream untargeted exits draw their victim from — a
    /// distinct stream from the schedule's, so adding an explicit event
    /// never shifts which replicas churn picks.
    pub fn target_rng(&self) -> Rng {
        Rng::new(self.seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0x243F6A8885A308D3))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::secs;

    #[test]
    fn schedule_is_deterministic_and_sorted() {
        let cfg = LifecycleConfig {
            churn_rate: 0.5,
            seed: 9,
            ..LifecycleConfig::default()
        };
        let a = cfg.schedule(secs(120.0));
        let b = cfg.schedule(secs(120.0));
        assert_eq!(a, b, "same seed, same schedule");
        assert!(!a.is_empty(), "0.5 ev/s over 120 s churns");
        assert!(a.windows(2).all(|w| w[0].time <= w[1].time));
        assert!(a.iter().all(|e| e.time < secs(120.0)));
        let c = LifecycleConfig { seed: 10, ..cfg }.schedule(secs(120.0));
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn explicit_events_merge_in_time_order() {
        let cfg = LifecycleConfig {
            events: vec![
                LifecycleEvent {
                    time: secs(50.0),
                    action: LifecycleAction::Crash,
                    target: Some(0),
                },
                LifecycleEvent {
                    time: secs(10.0),
                    action: LifecycleAction::Join,
                    target: None,
                },
            ],
            ..LifecycleConfig::default()
        };
        let s = cfg.schedule(secs(60.0));
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].action, LifecycleAction::Join);
        assert_eq!(s[1].target, Some(0));
        // events at/after the horizon are dropped
        assert_eq!(cfg.schedule(secs(30.0)).len(), 1);
    }

    #[test]
    fn enablement_gates() {
        let mut cfg = LifecycleConfig::default();
        assert!(!cfg.has_events() && !cfg.any_enabled());
        cfg.autoscaler.enabled = true;
        assert!(!cfg.has_events() && cfg.any_enabled());
        cfg.autoscaler.enabled = false;
        cfg.detector.enabled = true;
        assert!(!cfg.has_events() && cfg.any_enabled());
        cfg.detector.enabled = false;
        cfg.churn_rate = 1.0;
        assert!(cfg.has_events() && cfg.any_enabled());
    }

    #[test]
    fn detector_active_requires_enabled_and_nonzero_timeout() {
        let mut det = DetectorConfig::default();
        assert!(!det.active(), "defaults stay inert");
        det.enabled = true;
        assert!(det.active());
        det.suspicion_timeout = 0;
        assert!(!det.active(), "timeout 0 is the oracle path");
    }
}
