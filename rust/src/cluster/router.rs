//! The cluster router: dispatches an arrival stream across N replicas
//! under a pluggable routing strategy, with optional admission control
//! and overload migration (DESIGN.md "Cluster layer" / "Heterogeneous
//! fleets").
//!
//! The router is a discrete-event co-simulation driver: before each
//! routing decision it advances every replica's virtual clock to the
//! task's arrival time, so load signals are read at the moment the task
//! arrives — the same information a real front-end would have. After the
//! last arrival the fleet drains to a common horizon.
//!
//! Strategies (cf. SLOs-Serve, arXiv:2504.08784, and the deadline-aware
//! routing argument of arXiv:2504.14966):
//!   * [`RoutingStrategy::RoundRobin`] — the load-oblivious baseline;
//!   * [`RoutingStrategy::LeastLoaded`] — fewest outstanding tokens
//!     (queued + running);
//!   * [`RoutingStrategy::SloAware`] — largest Eq. 7 cycle headroom for
//!     the task's per-cycle quota under each replica's own device
//!     profile (see [`Replica::headroom`]), falling back to
//!     least-loaded on ties.
//!
//! Admission control ([`AdmissionConfig`], opt-in): a replica at its
//! per-class queued-but-unstarted bound is excluded from the decision —
//! the task *defers* to the strategy's next-best admissible replica —
//! and when no replica is admissible the task is *shed*: recorded on
//! [`ClusterReport::rejected`] and counted as an SLO violation, never
//! silently dropped.
//!
//! Overload migration (opt-in): at each routing boundary, a replica
//! whose Eq. 7 headroom has gone negative ([`Replica::overloaded`])
//! offers its queued-but-unstarted tasks back to the router, which
//! re-places each on the other replica with the largest headroom
//! (ties: least load, then lowest index — strategy-independent, since
//! migration is inherently load-driven). A task migrates at most once
//! (exactly-once delivery), and a pass only fires while some peer
//! still has positive headroom, so all-overloaded fleets do not churn.
//!
//! Running-task migration (opt-in on top of migration, DESIGN.md
//! "Memory model"): when withdrawing the queue is not enough — the
//! source is *still* overloaded by work already in service — the router
//! may hand a mid-generation task's KV cache to a peer over the
//! inter-replica link. Candidates are tasks the source has paused and
//! already evicted (zero service, cache off-device — giving them away
//! costs nothing; on an unconstrained device nothing is ever evicted,
//! so the pass is inert and legacy runs stay bit-identical). A handoff
//! only fires when the destination's Eq. 7 headroom for the task's
//! quota strictly exceeds the modelled transfer time of its cache
//! ([`MemoryConfig::handoff_cost`]); the fee is stamped on the task
//! and charged by the destination's serving loop when the task next
//! decodes, so handoff latency lands in the task's own timing record.
//! Exactly-once, cheapest-utility-first, deterministic.

use std::collections::HashSet;

use anyhow::Result;

use crate::coordinator::task::{Task, TaskId};
use crate::engine::memory::{MemoryConfig, MemoryStats};
use crate::metrics::{Attainment, LatencySummary};
use crate::util::Micros;

use super::fleet::{AdmissionConfig, AdmissionMode};
use super::replica::{Replica, ReplicaReport};

/// How the router picks a replica for each arriving task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingStrategy {
    /// Cycle through replicas in arrival order, ignoring load.
    RoundRobin,
    /// Fewest outstanding tokens (queued + running).
    LeastLoaded,
    /// Best Eq. 7 utility-rate headroom; least-loaded on ties.
    SloAware,
}

impl RoutingStrategy {
    /// Every strategy, in the order experiment tables report them.
    pub const ALL: [RoutingStrategy; 3] = [
        RoutingStrategy::RoundRobin,
        RoutingStrategy::LeastLoaded,
        RoutingStrategy::SloAware,
    ];

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => RoutingStrategy::RoundRobin,
            "least-loaded" | "ll" => RoutingStrategy::LeastLoaded,
            "slo-aware" | "slo" => RoutingStrategy::SloAware,
            other => anyhow::bail!(
                "unknown routing strategy '{other}' (round-robin|least-loaded|slo-aware)"
            ),
        })
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            RoutingStrategy::RoundRobin => "round-robin",
            RoutingStrategy::LeastLoaded => "least-loaded",
            RoutingStrategy::SloAware => "slo-aware",
        }
    }
}

/// Dispatches tasks across a fleet of [`Replica`]s.
pub struct Router {
    strategy: RoutingStrategy,
    replicas: Vec<Replica>,
    admission: AdmissionConfig,
    migration: bool,
    /// Running-task KV handoff (requires `migration`).
    migrate_running: bool,
    /// Prices KV handoffs (bytes per token, link bandwidth).
    memory: MemoryConfig,
    rr_next: usize,
    /// Admissibility-mask buffer reused across routing decisions (one
    /// decision runs per arrival — the cluster hot path allocates
    /// nothing whether or not admission control is on).
    admission_scratch: Vec<bool>,
    /// Per-replica headrooms computed by a headroom-admission pass,
    /// reused by the SLO-aware pick in the same decision so each
    /// replica's Eq. 7 demand is evaluated once per arrival, not twice.
    headroom_scratch: Vec<Micros>,
    /// Global ids that have migrated once already (exactly-once cap).
    migrated: HashSet<TaskId>,
    migrations: u64,
    migrated_running: u64,
    handoff_bytes: u64,
    handoff_us: Micros,
    rejected: Vec<Task>,
}

impl Router {
    /// Build a router over pre-constructed replicas (at least one).
    /// Admission control and migration start disabled — the PR 2
    /// homogeneous behaviour; opt in via [`Router::with_admission`] /
    /// [`Router::with_migration`] / [`Router::with_running_migration`].
    pub fn new(strategy: RoutingStrategy, replicas: Vec<Replica>) -> Self {
        assert!(!replicas.is_empty(), "a cluster needs at least one replica");
        // admission/migration bookkeeping indexes replicas by id
        assert!(
            replicas.iter().enumerate().all(|(i, r)| r.id() == i),
            "replica ids must equal their fleet position"
        );
        Router {
            strategy,
            replicas,
            admission: AdmissionConfig::default(),
            migration: false,
            migrate_running: false,
            memory: MemoryConfig::default(),
            rr_next: 0,
            admission_scratch: Vec::new(),
            headroom_scratch: Vec::new(),
            migrated: HashSet::new(),
            migrations: 0,
            migrated_running: 0,
            handoff_bytes: 0,
            handoff_us: 0,
            rejected: Vec::new(),
        }
    }

    /// Enable/configure per-class admission bounds.
    pub fn with_admission(mut self, admission: AdmissionConfig) -> Self {
        self.admission = admission;
        self
    }

    /// Enable or disable overload migration.
    pub fn with_migration(mut self, migration: bool) -> Self {
        self.migration = migration;
        self
    }

    /// Enable running-task KV-handoff migration, priced by `memory`
    /// (takes effect only while [`Router::with_migration`] is on).
    pub fn with_running_migration(mut self, enabled: bool, memory: MemoryConfig) -> Self {
        self.migrate_running = enabled;
        self.memory = memory;
        self
    }

    /// Number of replicas in the fleet.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Pick the replica for `task` under the configured strategy, or
    /// `None` when admission control sheds it (every replica is at its
    /// class bound). Tie-breaks are deterministic: least-loaded breaks
    /// ties by lowest replica index, and SLO-aware breaks headroom ties
    /// by least load, then lowest replica index — so cluster runs are
    /// reproducible for a fixed seed.
    pub fn decide(&mut self, task: &Task) -> Option<usize> {
        // the admissibility mask lives in a scratch buffer reused
        // across decisions (temporarily moved out so the strategy arms
        // below can borrow the router), and is only filled when
        // admission is on — the bench-tracked cluster/decide hot path
        // never allocates in steady state
        let mut mask = std::mem::take(&mut self.admission_scratch);
        let mut headrooms = std::mem::take(&mut self.headroom_scratch);
        mask.clear();
        headrooms.clear();
        let use_mask = self.admission.enabled;
        if use_mask {
            match self.admission.mode {
                AdmissionMode::QueueDepth => {
                    let bound = self.admission.bound_for(task.class);
                    mask.extend(
                        self.replicas
                            .iter()
                            .map(|r| r.queued_in_class(task.class) < bound),
                    );
                }
                AdmissionMode::Headroom => {
                    // keep the computed headrooms: the SLO-aware pick
                    // below reuses them, so headroom admission costs
                    // one Eq. 7 evaluation per replica, not two
                    let quota = task.slo.tokens_per_cycle();
                    for r in &self.replicas {
                        let h = r.headroom(quota);
                        headrooms.push(h);
                        mask.push(h > 0);
                    }
                }
            }
        }
        let open = |i: usize| !use_mask || mask[i];
        let pick = if !(0..self.replicas.len()).any(open) {
            None
        } else {
            Some(match self.strategy {
                RoutingStrategy::RoundRobin => {
                    // first admissible replica at or after the cursor
                    let start = self.rr_next;
                    let n = self.replicas.len();
                    let k = (0..n)
                        .find(|&k| open((start + k) % n))
                        .expect("some replica is admissible");
                    self.rr_next = start + k + 1;
                    (start + k) % n
                }
                RoutingStrategy::LeastLoaded => self
                    .replicas
                    .iter()
                    .filter(|r| open(r.id()))
                    .map(|r| (r.load_tokens(), r.id()))
                    .min()
                    .map(|(_, id)| id)
                    .unwrap(),
                RoutingStrategy::SloAware if !headrooms.is_empty() => self
                    .replicas
                    .iter()
                    .filter(|r| open(r.id()))
                    .map(|r| {
                        // same key as best_by_headroom, headroom cached
                        (std::cmp::Reverse(headrooms[r.id()]), r.load_tokens(), r.id())
                    })
                    .min()
                    .map(|(_, _, id)| id)
                    .expect("some replica is admissible"),
                RoutingStrategy::SloAware => {
                    let quota = task.slo.tokens_per_cycle();
                    self.best_by_headroom(quota, |r| open(r.id()))
                        .expect("some replica is admissible")
                }
            })
        };
        self.admission_scratch = mask;
        self.headroom_scratch = headrooms;
        pick
    }

    /// The replica with the most Eq. 7 headroom for `quota` among those
    /// `eligible` — ties broken by least load, then lowest index (the
    /// deterministic placement key shared by SLO-aware routing and
    /// migration re-placement). `None` when nothing is eligible.
    fn best_by_headroom<F: Fn(&Replica) -> bool>(&self, quota: u32, eligible: F) -> Option<usize> {
        self.best_by_headroom_with(quota, eligible).map(|(id, _)| id)
    }

    /// [`Router::best_by_headroom`] returning the winner's headroom as
    /// well, so callers comparing it against a fee don't re-evaluate
    /// the replica's whole Eq. 7 demand.
    fn best_by_headroom_with<F: Fn(&Replica) -> bool>(
        &self,
        quota: u32,
        eligible: F,
    ) -> Option<(usize, Micros)> {
        self.replicas
            .iter()
            .filter(|r| eligible(r))
            .map(|r| (std::cmp::Reverse(r.headroom(quota)), r.load_tokens(), r.id()))
            .min()
            .map(|(std::cmp::Reverse(headroom), _, id)| (id, headroom))
    }

    /// The migration pass run at each routing boundary: every
    /// overloaded replica offers its not-yet-migrated queued tasks
    /// back, and each is re-placed on the best *non-overloaded* peer by
    /// (headroom, load, index) — a task never burns its single allowed
    /// migration moving onto a replica that is itself overloaded. If
    /// every peer fills up mid-pass, the remaining offers fall back to
    /// the least-bad peer. Skipped entirely unless some peer has
    /// positive headroom. Migrated tasks were admitted when first
    /// routed, so re-placement deliberately ignores admission queue
    /// bounds (bounds govern new arrivals, not work already accepted).
    fn run_migrations(&mut self) {
        if !self.migration || self.replicas.len() < 2 {
            return;
        }
        for src in 0..self.replicas.len() {
            if !self.replicas[src].overloaded() {
                continue;
            }
            let peer_has_headroom = self
                .replicas
                .iter()
                .any(|r| r.id() != src && !r.overloaded());
            if !peer_has_headroom {
                continue;
            }
            let offered = self.replicas[src].withdraw_unmigrated(&self.migrated);
            for task in offered {
                let quota = task.slo.tokens_per_cycle();
                let dst = self
                    .best_by_headroom(quota, |r| r.id() != src && !r.overloaded())
                    .or_else(|| self.best_by_headroom(quota, |r| r.id() != src))
                    .expect("fleet has at least two replicas");
                self.migrated.insert(task.id);
                self.migrations += 1;
                self.replicas[dst].receive_migrated(task);
            }
        }
    }

    /// The running-task KV-handoff pass: after the queued pass, a
    /// replica the queue withdrawal could not decongest hands off
    /// mid-generation tasks it has paused *and* evicted (see
    /// [`Replica::running_candidates`] — work receiving zero service
    /// whose cache is off-device anyway), cheapest utility first, to
    /// the peer with the most Eq. 7 headroom — but only when that
    /// headroom gain strictly exceeds the modelled KV transfer time
    /// over the inter-replica link, so a handoff never costs more
    /// cycle time than it buys. The fee rides on the task
    /// (`pending_restore`) and is charged by the destination's serving
    /// loop at the task's next decode.
    fn run_running_migrations(&mut self) {
        if !self.migration || !self.migrate_running || self.replicas.len() < 2 {
            return;
        }
        for src in 0..self.replicas.len() {
            if !self.replicas[src].overloaded() {
                continue;
            }
            let candidates = self.replicas[src].running_candidates(&self.migrated);
            for (_, gid, quota, tokens) in candidates {
                if !self.replicas[src].overloaded() {
                    break;
                }
                let Some((dst, dst_headroom)) =
                    self.best_by_headroom_with(quota, |r| r.id() != src && !r.overloaded())
                else {
                    break;
                };
                let fee = self.memory.handoff_cost(tokens);
                if dst_headroom <= fee {
                    // Eq. 7 gain does not cover this cache's transfer; a
                    // later candidate may be smaller, so keep scanning
                    continue;
                }
                let task = self.replicas[src].extract_running(gid, fee);
                self.migrated.insert(gid);
                self.migrations += 1;
                self.migrated_running += 1;
                self.handoff_bytes += self.memory.bytes_for(tokens);
                self.handoff_us += fee;
                self.replicas[dst].receive_migrated(task);
            }
        }
    }

    /// Route and serve an entire workload (sorted by arrival, dense
    /// global ids), then drain the fleet for `drain` past the last
    /// arrival. Every replica ends at the same virtual horizon. `drain`
    /// must be long enough for every routed arrival to at least be
    /// delivered (a zero drain cannot deliver the final arrival);
    /// violating this panics rather than silently dropping tasks from
    /// the report.
    pub fn run(mut self, workload: Vec<Task>, drain: Micros) -> Result<ClusterReport> {
        assert!(
            workload.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "workload must be sorted by arrival"
        );
        let last_arrival = workload.last().map_or(0, |t| t.arrival);
        for task in workload {
            let now = task.arrival;
            for r in &mut self.replicas {
                r.run_until(now)?;
            }
            self.run_migrations();
            self.run_running_migrations();
            match self.decide(&task) {
                Some(pick) => self.replicas[pick].assign(task),
                None => self.rejected.push(task),
            }
        }
        let horizon = last_arrival + drain;
        for r in &mut self.replicas {
            r.run_until(horizon)?;
            assert!(
                r.pending() == 0,
                "drain window too small: replica {} has {} undelivered arrivals",
                r.id(),
                r.pending()
            );
        }
        Ok(ClusterReport {
            strategy: self.strategy.label(),
            migrations: self.migrations,
            migrated_running: self.migrated_running,
            handoff_bytes: self.handoff_bytes,
            handoff_us: self.handoff_us,
            rejected: self.rejected,
            replicas: self.replicas.into_iter().map(Replica::finish).collect(),
        })
    }
}

/// Outcome of a full cluster run.
pub struct ClusterReport {
    /// Routing strategy label (for reports).
    pub strategy: &'static str,
    /// Per-replica reports, with global task ids restored.
    pub replicas: Vec<ReplicaReport>,
    /// Tasks shed by admission control, untouched since arrival. They
    /// count as SLO violations in every fleet metric.
    pub rejected: Vec<Task>,
    /// Tasks re-placed by the overload-migration pass (each counted
    /// once; a task migrates at most once) — queued withdrawals plus
    /// running handoffs.
    pub migrations: u64,
    /// The subset of `migrations` that were running-task KV handoffs.
    pub migrated_running: u64,
    /// Total KV bytes transferred by running handoffs.
    pub handoff_bytes: u64,
    /// Total modelled transfer time of those handoffs (each fee also
    /// lands in the migrated task's own timing record).
    pub handoff_us: Micros,
}

impl ClusterReport {
    /// Scheduling policy the replicas ran (identical across the fleet).
    pub fn policy(&self) -> &'static str {
        self.replicas[0].report.policy
    }

    /// All tasks across the fleet — served *and* shed — sorted by
    /// global id. Shed tasks are unfinished, so attainment over this
    /// set counts them as violations.
    pub fn tasks(&self) -> Vec<Task> {
        let mut all: Vec<Task> = self
            .replicas
            .iter()
            .flat_map(|r| r.report.tasks.iter().cloned())
            .chain(self.rejected.iter().cloned())
            .collect();
        all.sort_by_key(|t| t.id);
        all
    }

    /// Tasks shed by admission control.
    pub fn rejected_count(&self) -> usize {
        self.rejected.len()
    }

    /// Fleet-wide SLO attainment over every routed *and* shed task.
    pub fn fleet_attainment(&self) -> Attainment {
        Attainment::compute(&self.tasks())
    }

    /// Fleet-wide TTFT/TPOT distribution over finished tasks.
    pub fn fleet_latency(&self) -> LatencySummary {
        LatencySummary::compute(&self.tasks())
    }

    /// Total engine steps executed across the fleet.
    pub fn total_steps(&self) -> u64 {
        self.replicas.iter().map(|r| r.report.steps).sum()
    }

    /// Total scheduling decisions (policy reschedules) across the
    /// fleet — the scale sweep's throughput numerator, alongside one
    /// routing decision per arrival.
    pub fn total_decisions(&self) -> u64 {
        self.replicas.iter().map(|r| r.report.decisions).sum()
    }

    /// Fleet-aggregated KV memory accounting: per-replica peaks summed
    /// (each device holds its own high-water mark) plus total swap /
    /// recompute / handoff transition counters.
    pub fn fleet_memory(&self) -> MemoryStats {
        let mut total = MemoryStats::default();
        for r in &self.replicas {
            total.merge(&r.report.memory);
        }
        total
    }

    /// Global ids across replica reports and the shed list: never
    /// overlapping, covering every task exactly once (checked by tests;
    /// here for observability).
    pub fn routed_ids(&self) -> Vec<TaskId> {
        let mut ids: Vec<TaskId> = self
            .replicas
            .iter()
            .flat_map(|r| r.report.tasks.iter().map(|t| t.id))
            .chain(self.rejected.iter().map(|t| t.id))
            .collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::DeviceProfile;
    use crate::coordinator::orca::OrcaPolicy;
    use crate::coordinator::task::TaskClass;
    use crate::engine::sim::SimEngine;
    use crate::util::secs;

    fn fleet(n: usize) -> Vec<Replica> {
        (0..n)
            .map(|i| {
                let profile = DeviceProfile::standard();
                Replica::new(
                    i,
                    Box::new(OrcaPolicy::new(profile.max_batch)),
                    Box::new(SimEngine::paper_calibrated()),
                    profile,
                )
            })
            .collect()
    }

    fn task(id: TaskId, arrival: Micros, out: u32) -> Task {
        Task::new(id, TaskClass::Voice, arrival, 16, out, 1.0)
    }

    #[test]
    fn strategy_parse_round_trips() {
        for s in RoutingStrategy::ALL {
            assert_eq!(RoutingStrategy::parse(s.label()).unwrap(), s);
        }
        assert_eq!(
            RoutingStrategy::parse("RR").unwrap(),
            RoutingStrategy::RoundRobin
        );
        assert!(RoutingStrategy::parse("random").is_err());
    }

    #[test]
    fn strategy_parse_rejects_unknown_and_empty_with_options() {
        for bad in ["", "  ", "robin", "least", "slo-awarex"] {
            let err = RoutingStrategy::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("round-robin|least-loaded|slo-aware"),
                "error for {bad:?} must list the valid strategies, got: {err}"
            );
            assert!(err.contains("unknown routing strategy"), "got: {err}");
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut router = Router::new(RoutingStrategy::RoundRobin, fleet(3));
        let t = task(0, 0, 5);
        let picks: Vec<usize> = (0..6).map(|_| router.decide(&t).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_empty_replica() {
        let mut replicas = fleet(2);
        replicas[0].assign(task(0, 0, 100));
        let mut router = Router::new(RoutingStrategy::LeastLoaded, replicas);
        assert_eq!(router.decide(&task(1, 0, 5)), Some(1));
    }

    #[test]
    fn slo_aware_avoids_contended_replica() {
        let mut replicas = fleet(2);
        // replica 0 is saturated with high-rate work
        for i in 0..8 {
            let mut t = task(i, 0, 200);
            t.class = TaskClass::RealTime;
            t.slo = crate::coordinator::task::SloSpec::real_time();
            replicas[0].assign(t);
        }
        let mut router = Router::new(RoutingStrategy::SloAware, replicas);
        assert_eq!(router.decide(&task(8, 0, 5)), Some(1));
    }

    #[test]
    fn admission_defers_then_sheds() {
        let admission =
            AdmissionConfig { enabled: true, rt_queue_bound: 1, nrt_queue_bound: 1, ..AdmissionConfig::default() };
        let mut router =
            Router::new(RoutingStrategy::RoundRobin, fleet(2)).with_admission(admission);
        // both replicas take one queued voice task; round-robin cursor
        // defers past full replicas deterministically
        let a = router.decide(&task(0, 0, 5)).unwrap();
        router.replicas[a].assign(task(0, 0, 5));
        let b = router.decide(&task(1, 0, 5)).unwrap();
        assert_ne!(a, b, "second task defers to the open replica");
        router.replicas[b].assign(task(1, 0, 5));
        // every replica is at the voice bound: shed
        assert_eq!(router.decide(&task(2, 0, 5)), None);
        // a different class still gets in (per-class bounds)
        let mut rt = task(3, 0, 5);
        rt.class = TaskClass::RealTime;
        rt.slo = crate::coordinator::task::SloSpec::real_time();
        assert!(router.decide(&rt).is_some());
    }

    #[test]
    fn headroom_admission_admits_deep_but_fast_queue() {
        // 6 queued voice tasks: deeper than a depth bound of 4, but the
        // Eq. 7 cycle with a 7th voice quota is 8*l(7) = 680 ms — well
        // under the cap, so headroom admission keeps the replica open
        let load = |mut replicas: Vec<Replica>| {
            for i in 0..6 {
                replicas[0].assign(task(i, 0, 5));
            }
            replicas
        };
        let depth = AdmissionConfig {
            enabled: true,
            mode: AdmissionMode::QueueDepth,
            rt_queue_bound: 4,
            nrt_queue_bound: 4,
        };
        let mut router =
            Router::new(RoutingStrategy::SloAware, load(fleet(1))).with_admission(depth);
        assert_eq!(router.decide(&task(6, 0, 5)), None, "depth bound sheds");

        let headroom = AdmissionConfig { mode: AdmissionMode::Headroom, ..depth };
        let mut router = Router::new(RoutingStrategy::SloAware, load(fleet(1)))
            .with_admission(headroom);
        assert_eq!(
            router.decide(&task(6, 0, 5)),
            Some(0),
            "headroom admits the deep-but-fast queue"
        );

        // and headroom *sheds* a shallow queue of expensive tasks: four
        // real-time quotas already exceed the cycle cap (20*l(4) > 1s)
        let mut replicas = fleet(1);
        for i in 0..4 {
            let mut t = task(i, 0, 100);
            t.class = TaskClass::RealTime;
            t.slo = crate::coordinator::task::SloSpec::real_time();
            replicas[0].assign(t);
        }
        let mut router =
            Router::new(RoutingStrategy::SloAware, replicas).with_admission(headroom);
        assert_eq!(router.decide(&task(9, 0, 5)), None, "no cycle headroom left");
    }

    #[test]
    fn running_migration_hands_off_exactly_once_with_fee() {
        use crate::cluster::replica::testutil::evicting_replica;
        use crate::engine::memory::MemoryConfig;
        // replica 0: overloaded, with three paused+evicted real-time
        // tasks (see testutil::evicting_replica); replica 1 idles.
        // Nothing is queued, so only the running pass can help.
        let idle = Replica::new(
            1,
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            DeviceProfile::standard(),
        );
        let replicas = vec![evicting_replica(0, 4), idle];
        let mut router = Router::new(RoutingStrategy::SloAware, replicas)
            .with_migration(true)
            .with_running_migration(true, MemoryConfig::default());
        router.replicas[0].run_until(secs(5.0)).unwrap();
        router.replicas[1].run_until(secs(5.0)).unwrap();
        assert!(router.replicas[0].overloaded());
        router.run_migrations();
        assert_eq!(router.migrations, 0, "nothing queued to withdraw");
        router.run_running_migrations();
        assert_eq!(
            router.migrated_running, 1,
            "one handoff clears the overload (4 -> 3 RT quotas)"
        );
        assert_eq!(router.migrations, 1);
        assert!(router.handoff_us > 0, "handoff priced over the link");
        assert!(router.handoff_bytes > 0);
        assert!(!router.replicas[0].overloaded());
        // the cheapest-utility candidate (global id 100) moved
        assert!(router.migrated.contains(&100));
        // a second pass is a no-op (no longer overloaded)
        router.run_running_migrations();
        assert_eq!(router.migrated_running, 1);

        // drain: the moved task finishes on replica 1 with its handoff
        // fee charged (pending_restore consumed at its first decode)
        for r in &mut router.replicas {
            r.run_until(secs(60.0)).unwrap();
        }
        let reports: Vec<_> = router.replicas.into_iter().map(Replica::finish).collect();
        let mut ids: Vec<TaskId> = reports
            .iter()
            .flat_map(|r| r.report.tasks.iter().map(|t| t.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![100, 101, 102, 103]);
        assert_eq!(reports[0].report.tasks.len(), 3, "husk dropped from source");
        let moved = reports[1]
            .report
            .tasks
            .iter()
            .find(|t| t.id == 100)
            .expect("handed-off task finishes on the destination");
        assert!(moved.is_finished());
        assert_eq!(moved.pending_restore, 0, "fee was charged on resume");
        assert!(moved.swap_ins >= 1);
        assert_eq!(reports[0].migrated_out, 1);
        assert_eq!(reports[1].migrated_in, 1);
        assert_eq!(
            reports[1].report.memory.handoff_restores, 1,
            "destination model counted the handoff restore"
        );
    }

    #[test]
    fn running_migration_requires_migration_gain_and_evicted_candidates() {
        use crate::cluster::replica::testutil::evicting_replica;
        use crate::engine::memory::MemoryConfig;
        let mk = |second: Replica| {
            let replicas = vec![evicting_replica(0, 4), second];
            Router::new(RoutingStrategy::SloAware, replicas)
        };
        let standard = |id: usize| {
            let profile = DeviceProfile::standard();
            Replica::new(
                id,
                Box::new(OrcaPolicy::new(profile.max_batch)),
                Box::new(SimEngine::paper_calibrated()),
                profile,
            )
        };
        // migrate_running without migration: the pass never fires
        let mut router =
            mk(standard(1)).with_running_migration(true, MemoryConfig::default());
        router.replicas[0].run_until(secs(5.0)).unwrap();
        router.run_running_migrations();
        assert_eq!(router.migrated_running, 0);

        // a link so slow the fee always exceeds the Eq. 7 gain: no handoff
        let slow = MemoryConfig { handoff_bandwidth: 1_000, ..MemoryConfig::default() };
        let mut router = mk(standard(1)).with_migration(true).with_running_migration(true, slow);
        router.replicas[0].run_until(secs(5.0)).unwrap();
        router.run_running_migrations();
        assert_eq!(router.migrated_running, 0, "gain must exceed the transfer time");
        assert!(router.replicas[0].overloaded(), "overload tolerated over paying");

        // an unconstrained overloaded replica never evicts, so it has
        // no handoff candidates: legacy runs are untouched even with
        // the flag on
        let mut replicas = fleet(2);
        for i in 0..4 {
            let mut t = task(i, 0, 60);
            t.class = TaskClass::RealTime;
            t.slo = crate::coordinator::task::SloSpec::real_time();
            replicas[0].assign(t);
        }
        let mut router = Router::new(RoutingStrategy::SloAware, replicas)
            .with_migration(true)
            .with_running_migration(true, MemoryConfig::default());
        router.replicas[0].run_until(secs(0.5)).unwrap();
        router.replicas[1].run_until(secs(0.5)).unwrap();
        assert!(router.replicas[0].overloaded());
        router.run_running_migrations();
        assert_eq!(router.migrated_running, 0, "no paused+evicted candidates");
    }

    #[test]
    fn run_covers_every_task_once() {
        let workload: Vec<Task> = (0..20).map(|i| task(i, i * 100_000, 10)).collect();
        let report = Router::new(RoutingStrategy::RoundRobin, fleet(4))
            .run(workload, secs(60.0))
            .unwrap();
        assert_eq!(report.routed_ids(), (0..20).collect::<Vec<_>>());
        assert_eq!(report.replicas.len(), 4);
        assert!(report.replicas.iter().all(|r| r.routed == 5));
        assert_eq!(report.rejected_count(), 0);
        assert_eq!(report.migrations, 0);
        let tasks = report.tasks();
        assert!(tasks.iter().all(|t| t.is_finished()));
        assert_eq!(report.policy(), "Orca");
    }

    #[test]
    fn shed_tasks_appear_in_report_as_violations() {
        let admission =
            AdmissionConfig { enabled: true, rt_queue_bound: 1, nrt_queue_bound: 1, ..AdmissionConfig::default() };
        // all tasks arrive at once: 2 replicas hold one each, rest shed
        let workload: Vec<Task> = (0..6).map(|i| task(i, 0, 10)).collect();
        let report = Router::new(RoutingStrategy::LeastLoaded, fleet(2))
            .with_admission(admission)
            .run(workload, secs(60.0))
            .unwrap();
        assert_eq!(report.rejected_count(), 4);
        assert_eq!(report.routed_ids(), (0..6).collect::<Vec<_>>());
        let a = report.fleet_attainment();
        assert_eq!(a.n_tasks, 6);
        assert_eq!(a.n_finished, 2, "shed tasks never finish");
        assert!(a.slo <= 2.0 / 6.0 + 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_fleet_rejected() {
        let _ = Router::new(RoutingStrategy::RoundRobin, Vec::new());
    }
}
