//! The cluster router: dispatches an arrival stream across N replicas
//! under a pluggable routing strategy, with optional admission control
//! and overload migration (DESIGN.md "Cluster layer" / "Heterogeneous
//! fleets").
//!
//! The router is the **lockstep reference engine**: a discrete-event
//! co-simulation driver that, before each routing decision, advances
//! every replica's virtual clock to the task's arrival time, so load
//! signals are read at the moment the task arrives — the same
//! information a real front-end would have. After the last arrival the
//! fleet drains to a common horizon. The event-driven engine
//! ([`crate::cluster::Orchestrator`]) reproduces this engine
//! bit-for-bit while only advancing replicas that have work; the
//! lockstep loop stays in-tree as the semantic reference the
//! equivalence suite pins the event engine against (DESIGN.md
//! "Event-driven cluster engine").
//!
//! All routing/admission/migration *decisions* live in the shared
//! [`Controller`](super::controller::Controller) — the router only owns
//! the lockstep time-advancement loop.
//!
//! Strategies (cf. SLOs-Serve, arXiv:2504.08784, and the deadline-aware
//! routing argument of arXiv:2504.14966):
//!   * [`RoutingStrategy::RoundRobin`] — the load-oblivious baseline;
//!   * [`RoutingStrategy::LeastLoaded`] — fewest outstanding tokens
//!     (queued + running);
//!   * [`RoutingStrategy::SloAware`] — largest Eq. 7 cycle headroom for
//!     the task's per-cycle quota under each replica's own device
//!     profile (see [`Replica::headroom`]), falling back to
//!     least-loaded on ties.
//!
//! Admission control ([`AdmissionConfig`], opt-in): a replica at its
//! per-class queued-but-unstarted bound is excluded from the decision —
//! the task *defers* to the strategy's next-best admissible replica —
//! and when no replica is admissible the task is *shed*: recorded on
//! [`ClusterReport::rejected`] and counted as an SLO violation, never
//! silently dropped.
//!
//! Overload migration (opt-in): at each routing boundary, a replica
//! whose Eq. 7 headroom has gone negative ([`Replica::overloaded`])
//! offers its queued-but-unstarted tasks back to the router, which
//! re-places each on the other replica with the largest headroom
//! (ties: least load, then lowest index — strategy-independent, since
//! migration is inherently load-driven). A task migrates at most once
//! (exactly-once delivery), and a pass only fires while some peer
//! still has positive headroom, so all-overloaded fleets do not churn.
//!
//! Running-task migration (opt-in on top of migration, DESIGN.md
//! "Memory model"): when withdrawing the queue is not enough — the
//! source is *still* overloaded by work already in service — the router
//! may hand a mid-generation task's KV cache to a peer over the
//! inter-replica link. Candidates are tasks the source has paused and
//! already evicted (zero service, cache off-device — giving them away
//! costs nothing; on an unconstrained device nothing is ever evicted,
//! so the pass is inert and legacy runs stay bit-identical). A handoff
//! only fires when the destination's Eq. 7 headroom for the task's
//! quota strictly exceeds the modelled transfer time of its cache
//! ([`MemoryConfig::handoff_cost`]); the fee is stamped on the task
//! and charged by the destination's serving loop when the task next
//! decodes, so handoff latency lands in the task's own timing record.
//! Exactly-once, cheapest-utility-first, deterministic.

use anyhow::Result;

use crate::coordinator::task::{Task, TaskId};
use crate::engine::memory::{MemoryConfig, MemoryStats};
use crate::metrics::{Attainment, LatencySummary};
use crate::util::Micros;

use super::controller::Controller;
use super::fleet::AdmissionConfig;
use super::replica::{Replica, ReplicaReport};

/// How the router picks a replica for each arriving task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingStrategy {
    /// Cycle through replicas in arrival order, ignoring load.
    RoundRobin,
    /// Fewest outstanding tokens (queued + running).
    LeastLoaded,
    /// Best Eq. 7 utility-rate headroom; least-loaded on ties.
    SloAware,
}

impl RoutingStrategy {
    /// Every strategy, in the order experiment tables report them.
    pub const ALL: [RoutingStrategy; 3] = [
        RoutingStrategy::RoundRobin,
        RoutingStrategy::LeastLoaded,
        RoutingStrategy::SloAware,
    ];

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => RoutingStrategy::RoundRobin,
            "least-loaded" | "ll" => RoutingStrategy::LeastLoaded,
            "slo-aware" | "slo" => RoutingStrategy::SloAware,
            other => anyhow::bail!(
                "unknown routing strategy '{other}' (round-robin|least-loaded|slo-aware)"
            ),
        })
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            RoutingStrategy::RoundRobin => "round-robin",
            RoutingStrategy::LeastLoaded => "least-loaded",
            RoutingStrategy::SloAware => "slo-aware",
        }
    }
}

/// Dispatches tasks across a fleet of [`Replica`]s in lockstep (the
/// reference engine).
pub struct Router {
    pub(crate) replicas: Vec<Replica>,
    pub(crate) ctl: Controller,
}

impl Router {
    /// Build a router over pre-constructed replicas (at least one).
    /// Admission control and migration start disabled — the PR 2
    /// homogeneous behaviour; opt in via [`Router::with_admission`] /
    /// [`Router::with_migration`] / [`Router::with_running_migration`].
    pub fn new(strategy: RoutingStrategy, replicas: Vec<Replica>) -> Self {
        assert!(!replicas.is_empty(), "a cluster needs at least one replica");
        // admission/migration bookkeeping indexes replicas by id
        assert!(
            replicas.iter().enumerate().all(|(i, r)| r.id() == i),
            "replica ids must equal their fleet position"
        );
        Router { replicas, ctl: Controller::new(strategy) }
    }

    /// Enable/configure per-class admission bounds.
    pub fn with_admission(mut self, admission: AdmissionConfig) -> Self {
        self.ctl.admission = admission;
        self
    }

    /// Enable or disable overload migration.
    pub fn with_migration(mut self, migration: bool) -> Self {
        self.ctl.migration = migration;
        self
    }

    /// Enable running-task KV-handoff migration, priced by `memory`
    /// (takes effect only while [`Router::with_migration`] is on).
    pub fn with_running_migration(mut self, enabled: bool, memory: MemoryConfig) -> Self {
        self.ctl.migrate_running = enabled;
        self.ctl.memory = memory;
        self
    }

    /// Number of replicas in the fleet.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Pick the replica for `task` under the configured strategy, or
    /// `None` when admission control sheds it (see
    /// [`Controller::decide`], which both engines share).
    pub fn decide(&mut self, task: &Task) -> Option<usize> {
        self.ctl.decide(&self.replicas, task)
    }

    /// The queued-task migration pass (shared [`Controller`] code).
    fn run_migrations(&mut self) {
        self.ctl.run_migrations(&mut self.replicas);
    }

    /// The running-task KV-handoff pass (shared [`Controller`] code).
    fn run_running_migrations(&mut self) {
        self.ctl.run_running_migrations(&mut self.replicas);
    }

    /// Route and serve an entire workload (sorted by arrival, dense
    /// global ids), then drain the fleet for `drain` past the last
    /// arrival. Every replica ends at the same virtual horizon. `drain`
    /// must be long enough for every routed arrival to at least be
    /// delivered (a zero drain cannot deliver the final arrival);
    /// violating this panics rather than silently dropping tasks from
    /// the report.
    pub fn run(mut self, workload: Vec<Task>, drain: Micros) -> Result<ClusterReport> {
        assert!(
            workload.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "workload must be sorted by arrival"
        );
        let last_arrival = workload.last().map_or(0, |t| t.arrival);
        for task in workload {
            let now = task.arrival;
            for r in &mut self.replicas {
                r.run_until(now)?;
            }
            self.run_migrations();
            self.run_running_migrations();
            match self.ctl.decide(&self.replicas, &task) {
                Some(pick) => self.replicas[pick].assign(task),
                None => self.ctl.reject(task),
            }
        }
        let horizon = last_arrival + drain;
        for r in &mut self.replicas {
            r.run_until(horizon)?;
            assert!(
                r.pending() == 0,
                "drain window too small: replica {} has {} undelivered arrivals",
                r.id(),
                r.pending()
            );
        }
        Ok(self.ctl.into_report(self.replicas))
    }
}

/// Elastic-fleet counters: lifecycle events applied, evacuation
/// outcomes, and autoscaler actions. All-zero (`Default`) for static
/// runs — the equivalence suite pins that.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ElasticStats {
    /// Replicas that crashed (resident KV lost).
    pub crashes: u64,
    /// Replicas that joined mid-run (lifecycle events; autoscaler grows
    /// count separately below).
    pub joins: u64,
    /// Replicas that left gracefully (KV handed off).
    pub leaves: u64,
    /// Evacuated tasks that were still queued (re-placed for free).
    pub evac_requeued: u64,
    /// Evacuated tasks that had started (re-admitted with a restore
    /// fee: recompute after a crash, KV handoff after a leave).
    pub evac_restarted: u64,
    /// Total recompute time charged to crash survivors (each fee also
    /// lands in the task's own timing record).
    pub evac_recompute_us: Micros,
    /// Fleet grows the autoscaler fired.
    pub autoscale_grows: u64,
    /// Fleet shrinks the autoscaler fired.
    pub autoscale_shrinks: u64,
    /// Grow decisions whose replica was still booting when the run
    /// ended (only with `[cluster.autoscaler] boot_delay_s` > 0; the
    /// default instant-warm joins keep this 0).
    pub autoscale_pending_boots: u64,
    /// Suspicion edges the failure detector raised (heartbeat age past
    /// the interval). Only with `[cluster.detector]` active; the
    /// remaining counters below share that gate.
    pub suspicions: u64,
    /// Suspicions cleared by a fresh heartbeat — overloaded-but-alive
    /// replicas that were never actually dead.
    pub false_suspicions: u64,
    /// Crashes confirmed dead by heartbeat timeout (each follows a
    /// detection *delay* during which dispatches went into limbo).
    pub detections: u64,
    /// Tasks found in limbo at confirmation (dispatched to the dead
    /// replica after its crash) and handed to the retry machinery.
    pub limbo_recovered: u64,
    /// Re-dispatch attempts made for recovered limbo tasks (every
    /// attempt counts, successful or not).
    pub retries: u64,
    /// Limbo tasks shed after exhausting their retry budget (or at
    /// `max_retries = 0`, immediately at confirmation).
    pub retry_exhausted: u64,
    /// Limbo tasks lost at the horizon: their replica's death was never
    /// confirmed (or a retry had no time left), so they drain as shed.
    pub limbo_lost: u64,
}

/// Outcome of a full cluster run.
pub struct ClusterReport {
    /// Routing strategy label (for reports).
    pub strategy: &'static str,
    /// Per-replica reports, with global task ids restored.
    pub replicas: Vec<ReplicaReport>,
    /// Tasks shed by admission control, untouched since arrival. They
    /// count as SLO violations in every fleet metric.
    pub rejected: Vec<Task>,
    /// Shed arrivals folded to a count in streaming mode (million-task
    /// traces) instead of being retained here — each is an SLO miss by
    /// definition. 0 outside streaming runs.
    pub rejected_folded: u64,
    /// Tasks re-placed by the overload-migration pass (each counted
    /// once; a task migrates at most once) — queued withdrawals plus
    /// running handoffs.
    pub migrations: u64,
    /// The subset of `migrations` that were running-task KV handoffs.
    pub migrated_running: u64,
    /// Total KV bytes transferred by running handoffs.
    pub handoff_bytes: u64,
    /// Total modelled transfer time of those handoffs (each fee also
    /// lands in the migrated task's own timing record).
    pub handoff_us: Micros,
    /// Migration passes actually executed (queued + running pass pairs
    /// past the enablement gate). The lockstep engine pays one per
    /// arrival boundary; the event engine pays O(overload episodes) —
    /// the ratio BENCH_8.json reports.
    pub migration_passes: u64,
    /// Edge-triggered `MigrationCheck` events the event engine handled
    /// (armed on overload transitions; 0 for lockstep runs).
    pub migration_checks: u64,
    /// Elastic-fleet counters (all-zero for static runs).
    pub elastic: ElasticStats,
}

impl ClusterReport {
    /// Scheduling policy the replicas ran (identical across the fleet).
    pub fn policy(&self) -> &'static str {
        self.replicas[0].report.policy
    }

    /// All tasks across the fleet — served *and* shed — sorted by
    /// global id. Shed tasks are unfinished, so attainment over this
    /// set counts them as violations.
    pub fn tasks(&self) -> Vec<Task> {
        let mut all: Vec<Task> = self
            .replicas
            .iter()
            .flat_map(|r| r.report.tasks.iter().cloned())
            .chain(self.rejected.iter().cloned())
            .collect();
        all.sort_by_key(|t| t.id);
        all
    }

    /// Tasks shed by admission control (retained plus folded).
    pub fn rejected_count(&self) -> usize {
        self.rejected.len() + self.rejected_folded as usize
    }

    /// Fleet-wide SLO attainment over every routed *and* shed task.
    pub fn fleet_attainment(&self) -> Attainment {
        Attainment::compute(&self.tasks())
    }

    /// Fleet-wide TTFT/TPOT distribution over finished tasks.
    pub fn fleet_latency(&self) -> LatencySummary {
        LatencySummary::compute(&self.tasks())
    }

    /// Total engine steps executed across the fleet.
    pub fn total_steps(&self) -> u64 {
        self.replicas.iter().map(|r| r.report.steps).sum()
    }

    /// Total scheduling decisions (policy reschedules) across the
    /// fleet — the scale sweep's throughput numerator, alongside one
    /// routing decision per arrival.
    pub fn total_decisions(&self) -> u64 {
        self.replicas.iter().map(|r| r.report.decisions).sum()
    }

    /// Total reschedules the fleet's policies proved unnecessary and
    /// skipped (see [`crate::server::RunReport::decisions_skipped`]).
    pub fn total_decisions_skipped(&self) -> u64 {
        self.replicas.iter().map(|r| r.report.decisions_skipped).sum()
    }

    /// Fleet-aggregated KV memory accounting: per-replica peaks summed
    /// (each device holds its own high-water mark) plus total swap /
    /// recompute / handoff transition counters.
    pub fn fleet_memory(&self) -> MemoryStats {
        let mut total = MemoryStats::default();
        for r in &self.replicas {
            total.merge(&r.report.memory);
        }
        total
    }

    /// Replicas still alive when the run ended (static fleets: all).
    pub fn alive_replicas(&self) -> usize {
        self.replicas.iter().filter(|r| r.alive).count()
    }

    /// Every task the run could not serve: admission-shed arrivals plus
    /// tasks the replicas shed mid-run (evacuation with no placement,
    /// or a KV cache too small for even one slot).
    pub fn shed_total(&self) -> u64 {
        self.rejected.len() as u64
            + self.rejected_folded
            + self.replicas.iter().map(|r| r.report.shed).sum::<u64>()
    }

    /// Global ids across replica reports and the shed list: never
    /// overlapping, covering every task exactly once (checked by tests;
    /// here for observability).
    pub fn routed_ids(&self) -> Vec<TaskId> {
        let mut ids: Vec<TaskId> = self
            .replicas
            .iter()
            .flat_map(|r| r.report.tasks.iter().map(|t| t.id))
            .chain(self.rejected.iter().map(|t| t.id))
            .collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::{AdmissionMode, DeviceProfile};
    use crate::coordinator::orca::OrcaPolicy;
    use crate::coordinator::task::TaskClass;
    use crate::engine::sim::SimEngine;
    use crate::util::secs;

    fn fleet(n: usize) -> Vec<Replica> {
        (0..n)
            .map(|i| {
                let profile = DeviceProfile::standard();
                Replica::new(
                    i,
                    Box::new(OrcaPolicy::new(profile.max_batch)),
                    Box::new(SimEngine::paper_calibrated()),
                    profile,
                )
            })
            .collect()
    }

    fn task(id: TaskId, arrival: Micros, out: u32) -> Task {
        Task::new(id, TaskClass::Voice, arrival, 16, out, 1.0)
    }

    #[test]
    fn strategy_parse_round_trips() {
        for s in RoutingStrategy::ALL {
            assert_eq!(RoutingStrategy::parse(s.label()).unwrap(), s);
        }
        assert_eq!(
            RoutingStrategy::parse("RR").unwrap(),
            RoutingStrategy::RoundRobin
        );
        assert!(RoutingStrategy::parse("random").is_err());
    }

    #[test]
    fn strategy_parse_rejects_unknown_and_empty_with_options() {
        for bad in ["", "  ", "robin", "least", "slo-awarex"] {
            let err = RoutingStrategy::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("round-robin|least-loaded|slo-aware"),
                "error for {bad:?} must list the valid strategies, got: {err}"
            );
            assert!(err.contains("unknown routing strategy"), "got: {err}");
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut router = Router::new(RoutingStrategy::RoundRobin, fleet(3));
        let t = task(0, 0, 5);
        let picks: Vec<usize> = (0..6).map(|_| router.decide(&t).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_empty_replica() {
        let mut replicas = fleet(2);
        replicas[0].assign(task(0, 0, 100));
        let mut router = Router::new(RoutingStrategy::LeastLoaded, replicas);
        assert_eq!(router.decide(&task(1, 0, 5)), Some(1));
    }

    #[test]
    fn slo_aware_avoids_contended_replica() {
        let mut replicas = fleet(2);
        // replica 0 is saturated with high-rate work
        for i in 0..8 {
            let mut t = task(i, 0, 200);
            t.class = TaskClass::RealTime;
            t.slo = crate::coordinator::task::SloSpec::real_time();
            replicas[0].assign(t);
        }
        let mut router = Router::new(RoutingStrategy::SloAware, replicas);
        assert_eq!(router.decide(&task(8, 0, 5)), Some(1));
    }

    #[test]
    fn admission_defers_then_sheds() {
        let admission =
            AdmissionConfig { enabled: true, rt_queue_bound: 1, nrt_queue_bound: 1, ..AdmissionConfig::default() };
        let mut router =
            Router::new(RoutingStrategy::RoundRobin, fleet(2)).with_admission(admission);
        // both replicas take one queued voice task; round-robin cursor
        // defers past full replicas deterministically
        let a = router.decide(&task(0, 0, 5)).unwrap();
        router.replicas[a].assign(task(0, 0, 5));
        let b = router.decide(&task(1, 0, 5)).unwrap();
        assert_ne!(a, b, "second task defers to the open replica");
        router.replicas[b].assign(task(1, 0, 5));
        // every replica is at the voice bound: shed
        assert_eq!(router.decide(&task(2, 0, 5)), None);
        // a different class still gets in (per-class bounds)
        let mut rt = task(3, 0, 5);
        rt.class = TaskClass::RealTime;
        rt.slo = crate::coordinator::task::SloSpec::real_time();
        assert!(router.decide(&rt).is_some());
    }

    #[test]
    fn headroom_admission_admits_deep_but_fast_queue() {
        // 6 queued voice tasks: deeper than a depth bound of 4, but the
        // Eq. 7 cycle with a 7th voice quota is 8*l(7) = 680 ms — well
        // under the cap, so headroom admission keeps the replica open
        let load = |mut replicas: Vec<Replica>| {
            for i in 0..6 {
                replicas[0].assign(task(i, 0, 5));
            }
            replicas
        };
        let depth = AdmissionConfig {
            enabled: true,
            mode: AdmissionMode::QueueDepth,
            rt_queue_bound: 4,
            nrt_queue_bound: 4,
        };
        let mut router =
            Router::new(RoutingStrategy::SloAware, load(fleet(1))).with_admission(depth);
        assert_eq!(router.decide(&task(6, 0, 5)), None, "depth bound sheds");

        let headroom = AdmissionConfig { mode: AdmissionMode::Headroom, ..depth };
        let mut router = Router::new(RoutingStrategy::SloAware, load(fleet(1)))
            .with_admission(headroom);
        assert_eq!(
            router.decide(&task(6, 0, 5)),
            Some(0),
            "headroom admits the deep-but-fast queue"
        );

        // and headroom *sheds* a shallow queue of expensive tasks: four
        // real-time quotas already exceed the cycle cap (20*l(4) > 1s)
        let mut replicas = fleet(1);
        for i in 0..4 {
            let mut t = task(i, 0, 100);
            t.class = TaskClass::RealTime;
            t.slo = crate::coordinator::task::SloSpec::real_time();
            replicas[0].assign(t);
        }
        let mut router =
            Router::new(RoutingStrategy::SloAware, replicas).with_admission(headroom);
        assert_eq!(router.decide(&task(9, 0, 5)), None, "no cycle headroom left");
    }

    #[test]
    fn running_migration_hands_off_exactly_once_with_fee() {
        use crate::cluster::replica::testutil::evicting_replica;
        use crate::engine::memory::MemoryConfig;
        // replica 0: overloaded, with three paused+evicted real-time
        // tasks (see testutil::evicting_replica); replica 1 idles.
        // Nothing is queued, so only the running pass can help.
        let idle = Replica::new(
            1,
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            DeviceProfile::standard(),
        );
        let replicas = vec![evicting_replica(0, 4), idle];
        let mut router = Router::new(RoutingStrategy::SloAware, replicas)
            .with_migration(true)
            .with_running_migration(true, MemoryConfig::default());
        router.replicas[0].run_until(secs(5.0)).unwrap();
        router.replicas[1].run_until(secs(5.0)).unwrap();
        assert!(router.replicas[0].overloaded());
        router.run_migrations();
        assert_eq!(router.ctl.migrations, 0, "nothing queued to withdraw");
        router.run_running_migrations();
        assert_eq!(
            router.ctl.migrated_running, 1,
            "one handoff clears the overload (4 -> 3 RT quotas)"
        );
        assert_eq!(router.ctl.migrations, 1);
        assert!(router.ctl.handoff_us > 0, "handoff priced over the link");
        assert!(router.ctl.handoff_bytes > 0);
        assert!(!router.replicas[0].overloaded());
        // the cheapest-utility candidate (global id 100) moved
        assert!(router.ctl.migrated.contains(&100));
        // a second pass is a no-op (no longer overloaded)
        router.run_running_migrations();
        assert_eq!(router.ctl.migrated_running, 1);

        // drain: the moved task finishes on replica 1 with its handoff
        // fee charged (pending_restore consumed at its first decode)
        for r in &mut router.replicas {
            r.run_until(secs(60.0)).unwrap();
        }
        let reports: Vec<_> = router.replicas.into_iter().map(Replica::finish).collect();
        let mut ids: Vec<TaskId> = reports
            .iter()
            .flat_map(|r| r.report.tasks.iter().map(|t| t.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![100, 101, 102, 103]);
        assert_eq!(reports[0].report.tasks.len(), 3, "husk dropped from source");
        let moved = reports[1]
            .report
            .tasks
            .iter()
            .find(|t| t.id == 100)
            .expect("handed-off task finishes on the destination");
        assert!(moved.is_finished());
        assert_eq!(moved.pending_restore, 0, "fee was charged on resume");
        assert!(moved.swap_ins >= 1);
        assert_eq!(reports[0].migrated_out, 1);
        assert_eq!(reports[1].migrated_in, 1);
        assert_eq!(
            reports[1].report.memory.handoff_restores, 1,
            "destination model counted the handoff restore"
        );
    }

    #[test]
    fn running_migration_requires_migration_gain_and_evicted_candidates() {
        use crate::cluster::replica::testutil::evicting_replica;
        use crate::engine::memory::MemoryConfig;
        let mk = |second: Replica| {
            let replicas = vec![evicting_replica(0, 4), second];
            Router::new(RoutingStrategy::SloAware, replicas)
        };
        let standard = |id: usize| {
            let profile = DeviceProfile::standard();
            Replica::new(
                id,
                Box::new(OrcaPolicy::new(profile.max_batch)),
                Box::new(SimEngine::paper_calibrated()),
                profile,
            )
        };
        // migrate_running without migration: the pass never fires
        let mut router =
            mk(standard(1)).with_running_migration(true, MemoryConfig::default());
        router.replicas[0].run_until(secs(5.0)).unwrap();
        router.run_running_migrations();
        assert_eq!(router.ctl.migrated_running, 0);

        // a link so slow the fee always exceeds the Eq. 7 gain: no handoff
        let slow = MemoryConfig { handoff_bandwidth: 1_000, ..MemoryConfig::default() };
        let mut router = mk(standard(1)).with_migration(true).with_running_migration(true, slow);
        router.replicas[0].run_until(secs(5.0)).unwrap();
        router.run_running_migrations();
        assert_eq!(router.ctl.migrated_running, 0, "gain must exceed the transfer time");
        assert!(router.replicas[0].overloaded(), "overload tolerated over paying");

        // an unconstrained overloaded replica never evicts, so it has
        // no handoff candidates: legacy runs are untouched even with
        // the flag on
        let mut replicas = fleet(2);
        for i in 0..4 {
            let mut t = task(i, 0, 60);
            t.class = TaskClass::RealTime;
            t.slo = crate::coordinator::task::SloSpec::real_time();
            replicas[0].assign(t);
        }
        let mut router = Router::new(RoutingStrategy::SloAware, replicas)
            .with_migration(true)
            .with_running_migration(true, MemoryConfig::default());
        router.replicas[0].run_until(secs(0.5)).unwrap();
        router.replicas[1].run_until(secs(0.5)).unwrap();
        assert!(router.replicas[0].overloaded());
        router.run_running_migrations();
        assert_eq!(router.ctl.migrated_running, 0, "no paused+evicted candidates");
    }

    #[test]
    fn run_covers_every_task_once() {
        let workload: Vec<Task> = (0..20).map(|i| task(i, i * 100_000, 10)).collect();
        let report = Router::new(RoutingStrategy::RoundRobin, fleet(4))
            .run(workload, secs(60.0))
            .unwrap();
        assert_eq!(report.routed_ids(), (0..20).collect::<Vec<_>>());
        assert_eq!(report.replicas.len(), 4);
        assert!(report.replicas.iter().all(|r| r.routed == 5));
        assert_eq!(report.rejected_count(), 0);
        assert_eq!(report.migrations, 0);
        let tasks = report.tasks();
        assert!(tasks.iter().all(|t| t.is_finished()));
        assert_eq!(report.policy(), "Orca");
    }

    #[test]
    fn shed_tasks_appear_in_report_as_violations() {
        let admission =
            AdmissionConfig { enabled: true, rt_queue_bound: 1, nrt_queue_bound: 1, ..AdmissionConfig::default() };
        // all tasks arrive at once: 2 replicas hold one each, rest shed
        let workload: Vec<Task> = (0..6).map(|i| task(i, 0, 10)).collect();
        let report = Router::new(RoutingStrategy::LeastLoaded, fleet(2))
            .with_admission(admission)
            .run(workload, secs(60.0))
            .unwrap();
        assert_eq!(report.rejected_count(), 4);
        assert_eq!(report.routed_ids(), (0..6).collect::<Vec<_>>());
        let a = report.fleet_attainment();
        assert_eq!(a.n_tasks, 6);
        assert_eq!(a.n_finished, 2, "shed tasks never finish");
        assert!(a.slo <= 2.0 / 6.0 + 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_fleet_rejected() {
        let _ = Router::new(RoutingStrategy::RoundRobin, Vec::new());
    }
}
