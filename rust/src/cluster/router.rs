//! The cluster router: dispatches an arrival stream across N replicas
//! under a pluggable routing strategy, with optional admission control
//! and overload migration (DESIGN.md "Cluster layer" / "Heterogeneous
//! fleets").
//!
//! The router is a discrete-event co-simulation driver: before each
//! routing decision it advances every replica's virtual clock to the
//! task's arrival time, so load signals are read at the moment the task
//! arrives — the same information a real front-end would have. After the
//! last arrival the fleet drains to a common horizon.
//!
//! Strategies (cf. SLOs-Serve, arXiv:2504.08784, and the deadline-aware
//! routing argument of arXiv:2504.14966):
//!   * [`RoutingStrategy::RoundRobin`] — the load-oblivious baseline;
//!   * [`RoutingStrategy::LeastLoaded`] — fewest outstanding tokens
//!     (queued + running);
//!   * [`RoutingStrategy::SloAware`] — largest Eq. 7 cycle headroom for
//!     the task's per-cycle quota under each replica's own device
//!     profile (see [`Replica::headroom`]), falling back to
//!     least-loaded on ties.
//!
//! Admission control ([`AdmissionConfig`], opt-in): a replica at its
//! per-class queued-but-unstarted bound is excluded from the decision —
//! the task *defers* to the strategy's next-best admissible replica —
//! and when no replica is admissible the task is *shed*: recorded on
//! [`ClusterReport::rejected`] and counted as an SLO violation, never
//! silently dropped.
//!
//! Overload migration (opt-in): at each routing boundary, a replica
//! whose Eq. 7 headroom has gone negative ([`Replica::overloaded`])
//! offers its queued-but-unstarted tasks back to the router, which
//! re-places each on the other replica with the largest headroom
//! (ties: least load, then lowest index — strategy-independent, since
//! migration is inherently load-driven). A task migrates at most once
//! (exactly-once delivery), and a pass only fires while some peer
//! still has positive headroom, so all-overloaded fleets do not churn.

use std::collections::HashSet;

use anyhow::Result;

use crate::coordinator::task::{Task, TaskId};
use crate::metrics::{Attainment, LatencySummary};
use crate::util::Micros;

use super::fleet::AdmissionConfig;
use super::replica::{Replica, ReplicaReport};

/// How the router picks a replica for each arriving task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingStrategy {
    /// Cycle through replicas in arrival order, ignoring load.
    RoundRobin,
    /// Fewest outstanding tokens (queued + running).
    LeastLoaded,
    /// Best Eq. 7 utility-rate headroom; least-loaded on ties.
    SloAware,
}

impl RoutingStrategy {
    /// Every strategy, in the order experiment tables report them.
    pub const ALL: [RoutingStrategy; 3] = [
        RoutingStrategy::RoundRobin,
        RoutingStrategy::LeastLoaded,
        RoutingStrategy::SloAware,
    ];

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => RoutingStrategy::RoundRobin,
            "least-loaded" | "ll" => RoutingStrategy::LeastLoaded,
            "slo-aware" | "slo" => RoutingStrategy::SloAware,
            other => anyhow::bail!(
                "unknown routing strategy '{other}' (round-robin|least-loaded|slo-aware)"
            ),
        })
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            RoutingStrategy::RoundRobin => "round-robin",
            RoutingStrategy::LeastLoaded => "least-loaded",
            RoutingStrategy::SloAware => "slo-aware",
        }
    }
}

/// Dispatches tasks across a fleet of [`Replica`]s.
pub struct Router {
    strategy: RoutingStrategy,
    replicas: Vec<Replica>,
    admission: AdmissionConfig,
    migration: bool,
    rr_next: usize,
    /// Global ids that have migrated once already (exactly-once cap).
    migrated: HashSet<TaskId>,
    migrations: u64,
    rejected: Vec<Task>,
}

impl Router {
    /// Build a router over pre-constructed replicas (at least one).
    /// Admission control and migration start disabled — the PR 2
    /// homogeneous behaviour; opt in via [`Router::with_admission`] /
    /// [`Router::with_migration`].
    pub fn new(strategy: RoutingStrategy, replicas: Vec<Replica>) -> Self {
        assert!(!replicas.is_empty(), "a cluster needs at least one replica");
        // admission/migration bookkeeping indexes replicas by id
        assert!(
            replicas.iter().enumerate().all(|(i, r)| r.id() == i),
            "replica ids must equal their fleet position"
        );
        Router {
            strategy,
            replicas,
            admission: AdmissionConfig::default(),
            migration: false,
            rr_next: 0,
            migrated: HashSet::new(),
            migrations: 0,
            rejected: Vec::new(),
        }
    }

    /// Enable/configure per-class admission bounds.
    pub fn with_admission(mut self, admission: AdmissionConfig) -> Self {
        self.admission = admission;
        self
    }

    /// Enable or disable overload migration.
    pub fn with_migration(mut self, migration: bool) -> Self {
        self.migration = migration;
        self
    }

    /// Number of replicas in the fleet.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Pick the replica for `task` under the configured strategy, or
    /// `None` when admission control sheds it (every replica is at its
    /// class bound). Tie-breaks are deterministic: least-loaded breaks
    /// ties by lowest replica index, and SLO-aware breaks headroom ties
    /// by least load, then lowest replica index — so cluster runs are
    /// reproducible for a fixed seed.
    pub fn decide(&mut self, task: &Task) -> Option<usize> {
        // the admissibility mask is only materialized when admission is
        // on, keeping the default path allocation-free (the bench-
        // tracked cluster/decide hot path)
        let mask: Option<Vec<bool>> = if self.admission.enabled {
            let bound = self.admission.bound_for(task.class);
            Some(
                self.replicas
                    .iter()
                    .map(|r| r.queued_in_class(task.class) < bound)
                    .collect(),
            )
        } else {
            None
        };
        let open = |i: usize| mask.as_ref().map_or(true, |m| m[i]);
        if !(0..self.replicas.len()).any(|i| open(i)) {
            return None;
        }
        Some(match self.strategy {
            RoutingStrategy::RoundRobin => {
                // first admissible replica at or after the cursor
                let start = self.rr_next;
                let n = self.replicas.len();
                let k = (0..n)
                    .find(|&k| open((start + k) % n))
                    .expect("some replica is admissible");
                self.rr_next = start + k + 1;
                (start + k) % n
            }
            RoutingStrategy::LeastLoaded => self
                .replicas
                .iter()
                .filter(|r| open(r.id()))
                .map(|r| (r.load_tokens(), r.id()))
                .min()
                .map(|(_, id)| id)
                .unwrap(),
            RoutingStrategy::SloAware => {
                let quota = task.slo.tokens_per_cycle();
                self.best_by_headroom(quota, |r| open(r.id()))
                    .expect("some replica is admissible")
            }
        })
    }

    /// The replica with the most Eq. 7 headroom for `quota` among those
    /// `eligible` — ties broken by least load, then lowest index (the
    /// deterministic placement key shared by SLO-aware routing and
    /// migration re-placement). `None` when nothing is eligible.
    fn best_by_headroom<F: Fn(&Replica) -> bool>(&self, quota: u32, eligible: F) -> Option<usize> {
        self.replicas
            .iter()
            .filter(|r| eligible(r))
            .map(|r| (std::cmp::Reverse(r.headroom(quota)), r.load_tokens(), r.id()))
            .min()
            .map(|(_, _, id)| id)
    }

    /// The migration pass run at each routing boundary: every
    /// overloaded replica offers its not-yet-migrated queued tasks
    /// back, and each is re-placed on the best *non-overloaded* peer by
    /// (headroom, load, index) — a task never burns its single allowed
    /// migration moving onto a replica that is itself overloaded. If
    /// every peer fills up mid-pass, the remaining offers fall back to
    /// the least-bad peer. Skipped entirely unless some peer has
    /// positive headroom. Migrated tasks were admitted when first
    /// routed, so re-placement deliberately ignores admission queue
    /// bounds (bounds govern new arrivals, not work already accepted).
    fn run_migrations(&mut self) {
        if !self.migration || self.replicas.len() < 2 {
            return;
        }
        for src in 0..self.replicas.len() {
            if !self.replicas[src].overloaded() {
                continue;
            }
            let peer_has_headroom = self
                .replicas
                .iter()
                .any(|r| r.id() != src && !r.overloaded());
            if !peer_has_headroom {
                continue;
            }
            let offered = self.replicas[src].withdraw_unmigrated(&self.migrated);
            for task in offered {
                let quota = task.slo.tokens_per_cycle();
                let dst = self
                    .best_by_headroom(quota, |r| r.id() != src && !r.overloaded())
                    .or_else(|| self.best_by_headroom(quota, |r| r.id() != src))
                    .expect("fleet has at least two replicas");
                self.migrated.insert(task.id);
                self.migrations += 1;
                self.replicas[dst].receive_migrated(task);
            }
        }
    }

    /// Route and serve an entire workload (sorted by arrival, dense
    /// global ids), then drain the fleet for `drain` past the last
    /// arrival. Every replica ends at the same virtual horizon. `drain`
    /// must be long enough for every routed arrival to at least be
    /// delivered (a zero drain cannot deliver the final arrival);
    /// violating this panics rather than silently dropping tasks from
    /// the report.
    pub fn run(mut self, workload: Vec<Task>, drain: Micros) -> Result<ClusterReport> {
        assert!(
            workload.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "workload must be sorted by arrival"
        );
        let last_arrival = workload.last().map_or(0, |t| t.arrival);
        for task in workload {
            let now = task.arrival;
            for r in &mut self.replicas {
                r.run_until(now)?;
            }
            self.run_migrations();
            match self.decide(&task) {
                Some(pick) => self.replicas[pick].assign(task),
                None => self.rejected.push(task),
            }
        }
        let horizon = last_arrival + drain;
        for r in &mut self.replicas {
            r.run_until(horizon)?;
            assert!(
                r.pending() == 0,
                "drain window too small: replica {} has {} undelivered arrivals",
                r.id(),
                r.pending()
            );
        }
        Ok(ClusterReport {
            strategy: self.strategy.label(),
            migrations: self.migrations,
            rejected: self.rejected,
            replicas: self.replicas.into_iter().map(Replica::finish).collect(),
        })
    }
}

/// Outcome of a full cluster run.
pub struct ClusterReport {
    /// Routing strategy label (for reports).
    pub strategy: &'static str,
    /// Per-replica reports, with global task ids restored.
    pub replicas: Vec<ReplicaReport>,
    /// Tasks shed by admission control, untouched since arrival. They
    /// count as SLO violations in every fleet metric.
    pub rejected: Vec<Task>,
    /// Tasks re-placed by the overload-migration pass (each counted
    /// once; a task migrates at most once).
    pub migrations: u64,
}

impl ClusterReport {
    /// Scheduling policy the replicas ran (identical across the fleet).
    pub fn policy(&self) -> &'static str {
        self.replicas[0].report.policy
    }

    /// All tasks across the fleet — served *and* shed — sorted by
    /// global id. Shed tasks are unfinished, so attainment over this
    /// set counts them as violations.
    pub fn tasks(&self) -> Vec<Task> {
        let mut all: Vec<Task> = self
            .replicas
            .iter()
            .flat_map(|r| r.report.tasks.iter().cloned())
            .chain(self.rejected.iter().cloned())
            .collect();
        all.sort_by_key(|t| t.id);
        all
    }

    /// Tasks shed by admission control.
    pub fn rejected_count(&self) -> usize {
        self.rejected.len()
    }

    /// Fleet-wide SLO attainment over every routed *and* shed task.
    pub fn fleet_attainment(&self) -> Attainment {
        Attainment::compute(&self.tasks())
    }

    /// Fleet-wide TTFT/TPOT distribution over finished tasks.
    pub fn fleet_latency(&self) -> LatencySummary {
        LatencySummary::compute(&self.tasks())
    }

    /// Total engine steps executed across the fleet.
    pub fn total_steps(&self) -> u64 {
        self.replicas.iter().map(|r| r.report.steps).sum()
    }

    /// Global ids across replica reports and the shed list: never
    /// overlapping, covering every task exactly once (checked by tests;
    /// here for observability).
    pub fn routed_ids(&self) -> Vec<TaskId> {
        let mut ids: Vec<TaskId> = self
            .replicas
            .iter()
            .flat_map(|r| r.report.tasks.iter().map(|t| t.id))
            .chain(self.rejected.iter().map(|t| t.id))
            .collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::DeviceProfile;
    use crate::coordinator::orca::OrcaPolicy;
    use crate::coordinator::task::TaskClass;
    use crate::engine::sim::SimEngine;
    use crate::util::secs;

    fn fleet(n: usize) -> Vec<Replica> {
        (0..n)
            .map(|i| {
                let profile = DeviceProfile::standard();
                Replica::new(
                    i,
                    Box::new(OrcaPolicy::new(profile.max_batch)),
                    Box::new(SimEngine::paper_calibrated()),
                    profile,
                )
            })
            .collect()
    }

    fn task(id: TaskId, arrival: Micros, out: u32) -> Task {
        Task::new(id, TaskClass::Voice, arrival, 16, out, 1.0)
    }

    #[test]
    fn strategy_parse_round_trips() {
        for s in RoutingStrategy::ALL {
            assert_eq!(RoutingStrategy::parse(s.label()).unwrap(), s);
        }
        assert_eq!(
            RoutingStrategy::parse("RR").unwrap(),
            RoutingStrategy::RoundRobin
        );
        assert!(RoutingStrategy::parse("random").is_err());
    }

    #[test]
    fn strategy_parse_rejects_unknown_and_empty_with_options() {
        for bad in ["", "  ", "robin", "least", "slo-awarex"] {
            let err = RoutingStrategy::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("round-robin|least-loaded|slo-aware"),
                "error for {bad:?} must list the valid strategies, got: {err}"
            );
            assert!(err.contains("unknown routing strategy"), "got: {err}");
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut router = Router::new(RoutingStrategy::RoundRobin, fleet(3));
        let t = task(0, 0, 5);
        let picks: Vec<usize> = (0..6).map(|_| router.decide(&t).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_empty_replica() {
        let mut replicas = fleet(2);
        replicas[0].assign(task(0, 0, 100));
        let mut router = Router::new(RoutingStrategy::LeastLoaded, replicas);
        assert_eq!(router.decide(&task(1, 0, 5)), Some(1));
    }

    #[test]
    fn slo_aware_avoids_contended_replica() {
        let mut replicas = fleet(2);
        // replica 0 is saturated with high-rate work
        for i in 0..8 {
            let mut t = task(i, 0, 200);
            t.class = TaskClass::RealTime;
            t.slo = crate::coordinator::task::SloSpec::real_time();
            replicas[0].assign(t);
        }
        let mut router = Router::new(RoutingStrategy::SloAware, replicas);
        assert_eq!(router.decide(&task(8, 0, 5)), Some(1));
    }

    #[test]
    fn admission_defers_then_sheds() {
        let admission =
            AdmissionConfig { enabled: true, rt_queue_bound: 1, nrt_queue_bound: 1 };
        let mut router =
            Router::new(RoutingStrategy::RoundRobin, fleet(2)).with_admission(admission);
        // both replicas take one queued voice task; round-robin cursor
        // defers past full replicas deterministically
        let a = router.decide(&task(0, 0, 5)).unwrap();
        router.replicas[a].assign(task(0, 0, 5));
        let b = router.decide(&task(1, 0, 5)).unwrap();
        assert_ne!(a, b, "second task defers to the open replica");
        router.replicas[b].assign(task(1, 0, 5));
        // every replica is at the voice bound: shed
        assert_eq!(router.decide(&task(2, 0, 5)), None);
        // a different class still gets in (per-class bounds)
        let mut rt = task(3, 0, 5);
        rt.class = TaskClass::RealTime;
        rt.slo = crate::coordinator::task::SloSpec::real_time();
        assert!(router.decide(&rt).is_some());
    }

    #[test]
    fn run_covers_every_task_once() {
        let workload: Vec<Task> = (0..20).map(|i| task(i, i * 100_000, 10)).collect();
        let report = Router::new(RoutingStrategy::RoundRobin, fleet(4))
            .run(workload, secs(60.0))
            .unwrap();
        assert_eq!(report.routed_ids(), (0..20).collect::<Vec<_>>());
        assert_eq!(report.replicas.len(), 4);
        assert!(report.replicas.iter().all(|r| r.routed == 5));
        assert_eq!(report.rejected_count(), 0);
        assert_eq!(report.migrations, 0);
        let tasks = report.tasks();
        assert!(tasks.iter().all(|t| t.is_finished()));
        assert_eq!(report.policy(), "Orca");
    }

    #[test]
    fn shed_tasks_appear_in_report_as_violations() {
        let admission =
            AdmissionConfig { enabled: true, rt_queue_bound: 1, nrt_queue_bound: 1 };
        // all tasks arrive at once: 2 replicas hold one each, rest shed
        let workload: Vec<Task> = (0..6).map(|i| task(i, 0, 10)).collect();
        let report = Router::new(RoutingStrategy::LeastLoaded, fleet(2))
            .with_admission(admission)
            .run(workload, secs(60.0))
            .unwrap();
        assert_eq!(report.rejected_count(), 4);
        assert_eq!(report.routed_ids(), (0..6).collect::<Vec<_>>());
        let a = report.fleet_attainment();
        assert_eq!(a.n_tasks, 6);
        assert_eq!(a.n_finished, 2, "shed tasks never finish");
        assert!(a.slo <= 2.0 / 6.0 + 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_fleet_rejected() {
        let _ = Router::new(RoutingStrategy::RoundRobin, Vec::new());
    }
}
