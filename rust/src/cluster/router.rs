//! The cluster router: dispatches an arrival stream across N replicas
//! under a pluggable routing strategy (DESIGN.md "Cluster layer").
//!
//! The router is a discrete-event co-simulation driver: before each
//! routing decision it advances every replica's virtual clock to the
//! task's arrival time, so load signals are read at the moment the task
//! arrives — the same information a real front-end would have. After the
//! last arrival the fleet drains to a common horizon.
//!
//! Strategies (cf. SLOs-Serve, arXiv:2504.08784, and the deadline-aware
//! routing argument of arXiv:2504.14966):
//!   * [`RoutingStrategy::RoundRobin`] — the load-oblivious baseline;
//!   * [`RoutingStrategy::LeastLoaded`] — fewest outstanding tokens
//!     (queued + running);
//!   * [`RoutingStrategy::SloAware`] — largest Eq. 7 cycle headroom for
//!     the task's per-cycle quota (see [`Replica::headroom`]), falling
//!     back to least-loaded on ties.

use anyhow::Result;

use crate::coordinator::task::{Task, TaskId};
use crate::metrics::{Attainment, LatencySummary};
use crate::util::Micros;

use super::replica::{Replica, ReplicaReport};

/// How the router picks a replica for each arriving task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingStrategy {
    /// Cycle through replicas in arrival order, ignoring load.
    RoundRobin,
    /// Fewest outstanding tokens (queued + running).
    LeastLoaded,
    /// Best Eq. 7 utility-rate headroom; least-loaded on ties.
    SloAware,
}

impl RoutingStrategy {
    /// Every strategy, in the order experiment tables report them.
    pub const ALL: [RoutingStrategy; 3] = [
        RoutingStrategy::RoundRobin,
        RoutingStrategy::LeastLoaded,
        RoutingStrategy::SloAware,
    ];

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => RoutingStrategy::RoundRobin,
            "least-loaded" | "ll" => RoutingStrategy::LeastLoaded,
            "slo-aware" | "slo" => RoutingStrategy::SloAware,
            other => anyhow::bail!(
                "unknown routing strategy '{other}' (round-robin|least-loaded|slo-aware)"
            ),
        })
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            RoutingStrategy::RoundRobin => "round-robin",
            RoutingStrategy::LeastLoaded => "least-loaded",
            RoutingStrategy::SloAware => "slo-aware",
        }
    }
}

/// Dispatches tasks across a fleet of [`Replica`]s.
pub struct Router {
    strategy: RoutingStrategy,
    replicas: Vec<Replica>,
    /// Scheduling-cycle cap used for SLO-aware headroom scoring.
    cycle_cap: Micros,
    rr_next: usize,
}

impl Router {
    /// Build a router over pre-constructed replicas (at least one).
    pub fn new(strategy: RoutingStrategy, replicas: Vec<Replica>, cycle_cap: Micros) -> Self {
        assert!(!replicas.is_empty(), "a cluster needs at least one replica");
        Router { strategy, replicas, cycle_cap, rr_next: 0 }
    }

    /// Number of replicas in the fleet.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Pick the replica for `task` under the configured strategy. All
    /// tie-breaks are deterministic (lowest replica index), so cluster
    /// runs are reproducible for a fixed seed.
    pub fn decide(&mut self, task: &Task) -> usize {
        match self.strategy {
            RoutingStrategy::RoundRobin => {
                let i = self.rr_next % self.replicas.len();
                self.rr_next += 1;
                i
            }
            RoutingStrategy::LeastLoaded => self
                .replicas
                .iter()
                .map(|r| (r.load_tokens(), r.id()))
                .min()
                .map(|(_, id)| id)
                .unwrap(),
            RoutingStrategy::SloAware => {
                let quota = task.slo.tokens_per_cycle();
                self.replicas
                    .iter()
                    .map(|r| {
                        // max headroom, then min load, then lowest index
                        (
                            std::cmp::Reverse(r.headroom(quota, self.cycle_cap)),
                            r.load_tokens(),
                            r.id(),
                        )
                    })
                    .min()
                    .map(|(_, _, id)| id)
                    .unwrap()
            }
        }
    }

    /// Route and serve an entire workload (sorted by arrival, dense
    /// global ids), then drain the fleet for `drain` past the last
    /// arrival. Every replica ends at the same virtual horizon. `drain`
    /// must be long enough for every routed arrival to at least be
    /// delivered (a zero drain cannot deliver the final arrival);
    /// violating this panics rather than silently dropping tasks from
    /// the report.
    pub fn run(mut self, workload: Vec<Task>, drain: Micros) -> Result<ClusterReport> {
        assert!(
            workload.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "workload must be sorted by arrival"
        );
        let last_arrival = workload.last().map_or(0, |t| t.arrival);
        for task in workload {
            let now = task.arrival;
            for r in &mut self.replicas {
                r.run_until(now)?;
            }
            let pick = self.decide(&task);
            self.replicas[pick].assign(task);
        }
        let horizon = last_arrival + drain;
        for r in &mut self.replicas {
            r.run_until(horizon)?;
            assert!(
                r.pending() == 0,
                "drain window too small: replica {} has {} undelivered arrivals",
                r.id(),
                r.pending()
            );
        }
        Ok(ClusterReport {
            strategy: self.strategy.label(),
            replicas: self.replicas.into_iter().map(Replica::finish).collect(),
        })
    }
}

/// Outcome of a full cluster run.
pub struct ClusterReport {
    /// Routing strategy label (for reports).
    pub strategy: &'static str,
    /// Per-replica reports, with global task ids restored.
    pub replicas: Vec<ReplicaReport>,
}

impl ClusterReport {
    /// Scheduling policy the replicas ran (identical across the fleet).
    pub fn policy(&self) -> &'static str {
        self.replicas[0].report.policy
    }

    /// All tasks across the fleet, sorted by global id.
    pub fn tasks(&self) -> Vec<Task> {
        let mut all: Vec<Task> = self
            .replicas
            .iter()
            .flat_map(|r| r.report.tasks.iter().cloned())
            .collect();
        all.sort_by_key(|t| t.id);
        all
    }

    /// Fleet-wide SLO attainment over every routed task.
    pub fn fleet_attainment(&self) -> Attainment {
        Attainment::compute(&self.tasks())
    }

    /// Fleet-wide TTFT/TPOT distribution over finished tasks.
    pub fn fleet_latency(&self) -> LatencySummary {
        LatencySummary::compute(&self.tasks())
    }

    /// Total engine steps executed across the fleet.
    pub fn total_steps(&self) -> u64 {
        self.replicas.iter().map(|r| r.report.steps).sum()
    }

    /// Global ids routed to each replica never overlap and cover every
    /// task exactly once (checked by tests; here for observability).
    pub fn routed_ids(&self) -> Vec<TaskId> {
        let mut ids: Vec<TaskId> = self
            .replicas
            .iter()
            .flat_map(|r| r.report.tasks.iter().map(|t| t.id))
            .collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::orca::OrcaPolicy;
    use crate::coordinator::task::TaskClass;
    use crate::engine::latency::LatencyModel;
    use crate::engine::sim::SimEngine;
    use crate::util::secs;

    fn fleet(n: usize) -> Vec<Replica> {
        (0..n)
            .map(|i| {
                Replica::new(
                    i,
                    Box::new(OrcaPolicy::new(32)),
                    Box::new(SimEngine::paper_calibrated()),
                    LatencyModel::paper_calibrated(),
                )
            })
            .collect()
    }

    fn task(id: TaskId, arrival: Micros, out: u32) -> Task {
        Task::new(id, TaskClass::Voice, arrival, 16, out, 1.0)
    }

    #[test]
    fn strategy_parse_round_trips() {
        for s in RoutingStrategy::ALL {
            assert_eq!(RoutingStrategy::parse(s.label()).unwrap(), s);
        }
        assert_eq!(
            RoutingStrategy::parse("RR").unwrap(),
            RoutingStrategy::RoundRobin
        );
        assert!(RoutingStrategy::parse("random").is_err());
    }

    #[test]
    fn round_robin_cycles() {
        let mut router = Router::new(RoutingStrategy::RoundRobin, fleet(3), 1_000_000);
        let t = task(0, 0, 5);
        let picks: Vec<usize> = (0..6).map(|_| router.decide(&t)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_empty_replica() {
        let mut replicas = fleet(2);
        replicas[0].assign(task(0, 0, 100));
        let mut router = Router::new(RoutingStrategy::LeastLoaded, replicas, 1_000_000);
        assert_eq!(router.decide(&task(1, 0, 5)), 1);
    }

    #[test]
    fn slo_aware_avoids_contended_replica() {
        let mut replicas = fleet(2);
        // replica 0 is saturated with high-rate work
        for i in 0..8 {
            let mut t = task(i, 0, 200);
            t.class = TaskClass::RealTime;
            t.slo = crate::coordinator::task::SloSpec::real_time();
            replicas[0].assign(t);
        }
        let mut router = Router::new(RoutingStrategy::SloAware, replicas, 1_000_000);
        assert_eq!(router.decide(&task(8, 0, 5)), 1);
    }

    #[test]
    fn run_covers_every_task_once() {
        let workload: Vec<Task> =
            (0..20).map(|i| task(i, i * 100_000, 10)).collect();
        let report = Router::new(RoutingStrategy::RoundRobin, fleet(4), 1_000_000)
            .run(workload, secs(60.0))
            .unwrap();
        assert_eq!(report.routed_ids(), (0..20).collect::<Vec<_>>());
        assert_eq!(report.replicas.len(), 4);
        assert!(report.replicas.iter().all(|r| r.routed == 5));
        let tasks = report.tasks();
        assert!(tasks.iter().all(|t| t.is_finished()));
        assert_eq!(report.policy(), "Orca");
    }

    #[test]
    #[should_panic]
    fn empty_fleet_rejected() {
        let _ = Router::new(RoutingStrategy::RoundRobin, Vec::new(), 1_000_000);
    }
}
