//! Heartbeat-driven failure detection: suspicion, confirmation, and
//! the false-positive path back (DESIGN.md "Failure detection &
//! recovery").
//!
//! PR 7's elastic fleets had oracle failure visibility: the instant a
//! replica crashed, the controller knew and evacuated. The
//! [`FailureDetector`] replaces the oracle with the signal real edge
//! fleets actually have — heartbeats. Every
//! [`heartbeat_interval`](super::DetectorConfig::heartbeat_interval)
//! the orchestrator ticks the detector; each *functioning* replica
//! emits a heartbeat that arrives after its current Eq. 7 cycle lag
//! ([`Replica::cycle_lag`](super::Replica::cycle_lag)), so an
//! overloaded replica heartbeats late for organic reasons. A crashed
//! replica is silenced (it emits nothing), and until its heartbeat age
//! crosses [`suspicion_timeout`](super::DetectorConfig::suspicion_timeout)
//! the router keeps dispatching into it — those tasks sit in limbo and
//! are recovered with bounded retry/backoff at confirmation (the
//! orchestrator's job; see `cluster/orchestrator.rs`).
//!
//! The per-replica suspicion state machine, evaluated at each tick
//! against heartbeat age `now - last_heartbeat_arrival`:
//!
//!   * **healthy → suspected** when age exceeds `heartbeat_interval`
//!     (one full tick missed). Suspected replicas are excluded from new
//!     placement and migration destinations, which gently drains them.
//!   * **suspected → healthy** when a fresh heartbeat lands (age back
//!     within `heartbeat_interval`) — a *false suspicion*, counted but
//!     harmless. Only overloaded-but-alive replicas take this edge;
//!     the dead never heartbeat again.
//!   * **suspected → confirmed dead** when age reaches
//!     `suspicion_timeout` *and* the replica is actually silenced.
//!     Confirmation is gated on the simulation's ground truth so a
//!     false suspicion can never escalate to a false kill — a live
//!     replica lagging past the timeout stays suspected (drained, not
//!     evacuated) until its heartbeats catch up. Real detectors pay
//!     false kills instead; the simulation charges the milder price so
//!     task conservation stays provable.
//!
//! The detector holds no routing state of its own — the orchestrator
//! applies each [`Verdict`] to the controller's `suspected` mask and
//! counters, keeping this type a pure clock-in/verdict-out machine
//! that the Python mirror reproduces line for line.

use super::lifecycle::DetectorConfig;
use crate::util::Micros;

/// Transition produced by one suspicion-machine tick for one replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// No transition this tick.
    None,
    /// Freshly suspected: heartbeat age crossed `heartbeat_interval`.
    Suspect,
    /// Suspicion cleared by a fresh heartbeat — a false suspicion.
    Unsuspect,
    /// Confirmed dead: silenced and age reached `suspicion_timeout`.
    Confirm,
}

/// The heartbeat bookkeeping behind the suspicion state machine: per
/// replica, the arrival time of the freshest heartbeat folded in, the
/// in-flight heartbeats still travelling, and the current suspicion
/// flag (mirrored into the controller's placement mask by the
/// orchestrator).
#[derive(Debug, Clone)]
pub struct FailureDetector {
    cfg: DetectorConfig,
    /// Arrival time of the freshest heartbeat seen, per replica. A
    /// replica admitted at time `t` starts with `last_hb = t` so it is
    /// not born pre-suspected.
    last_hb: Vec<Micros>,
    /// Emitted-but-not-yet-arrived heartbeat arrival times, per
    /// replica. Arrivals are folded into `last_hb` lazily at each tick.
    pending: Vec<Vec<Micros>>,
    /// Detector-local suspicion flags (drive the verdict edges).
    suspected: Vec<bool>,
}

impl FailureDetector {
    /// Detector for an initial fleet of `n` replicas at virtual time 0.
    pub fn new(cfg: DetectorConfig, n: usize) -> Self {
        FailureDetector {
            cfg,
            last_hb: vec![0; n],
            pending: vec![Vec::new(); n],
            suspected: vec![false; n],
        }
    }

    /// The config the detector was built with.
    pub fn cfg(&self) -> &DetectorConfig {
        &self.cfg
    }

    /// Grow the tracked set to `n` replicas (joiners). New entries
    /// start with a synthetic heartbeat at `now` — a replica that
    /// joins mid-run is healthy until it actually misses a tick.
    pub fn ensure(&mut self, n: usize, now: Micros) {
        while self.last_hb.len() < n {
            self.last_hb.push(now);
            self.pending.push(Vec::new());
            self.suspected.push(false);
        }
    }

    /// Record a heartbeat emitted by replica `i` at `tick`, arriving
    /// `lag` later (the replica's current Eq. 7 cycle overrun — an
    /// overloaded replica's heartbeat travels late).
    pub fn emit(&mut self, i: usize, tick: Micros, lag: Micros) {
        self.pending[i].push(tick.saturating_add(lag));
    }

    /// Fold arrived heartbeats for replica `i` and run one suspicion
    /// step at `now`. `dead` is the simulation's ground truth (the
    /// orchestrator's silenced flag): only dead replicas can be
    /// confirmed; live laggards cap at suspected.
    pub fn tick(&mut self, i: usize, now: Micros, dead: bool) -> Verdict {
        let pend = &mut self.pending[i];
        let mut k = 0;
        while k < pend.len() {
            if pend[k] <= now {
                let arrived = pend.swap_remove(k);
                if arrived > self.last_hb[i] {
                    self.last_hb[i] = arrived;
                }
            } else {
                k += 1;
            }
        }
        let age = now.saturating_sub(self.last_hb[i]);
        if dead && age >= self.cfg.suspicion_timeout {
            self.suspected[i] = true;
            return Verdict::Confirm;
        }
        if age > self.cfg.heartbeat_interval {
            if !self.suspected[i] {
                self.suspected[i] = true;
                return Verdict::Suspect;
            }
        } else if self.suspected[i] {
            self.suspected[i] = false;
            return Verdict::Unsuspect;
        }
        Verdict::None
    }

    /// Current suspicion flag for replica `i`.
    pub fn is_suspected(&self, i: usize) -> bool {
        self.suspected.get(i).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det() -> FailureDetector {
        let cfg = DetectorConfig {
            enabled: true,
            heartbeat_interval: 100,
            suspicion_timeout: 300,
            ..DetectorConfig::default()
        };
        FailureDetector::new(cfg, 2)
    }

    #[test]
    fn on_time_heartbeats_never_suspect() {
        let mut d = det();
        for tick in 1..=10u64 {
            let t = tick * 100;
            d.emit(0, t, 0);
            assert_eq!(d.tick(0, t, false), Verdict::None);
            assert!(!d.is_suspected(0));
        }
    }

    #[test]
    fn silence_suspects_then_confirms_when_dead() {
        let mut d = det();
        // replica 1 heartbeats; replica 0 went silent after t=0
        assert_eq!(d.tick(0, 100, true), Verdict::None, "age == interval");
        assert_eq!(d.tick(0, 200, true), Verdict::Suspect);
        assert_eq!(d.tick(0, 200, true), Verdict::None, "edge, not level");
        assert_eq!(d.tick(0, 300, true), Verdict::Confirm, "age == timeout");
    }

    #[test]
    fn late_heartbeat_is_a_false_suspicion() {
        let mut d = det();
        d.emit(0, 100, 150); // overloaded: arrives at 250
        assert_eq!(d.tick(0, 200, false), Verdict::Suspect);
        assert!(d.is_suspected(0));
        assert_eq!(d.tick(0, 300, false), Verdict::Unsuspect, "hb landed at 250");
        assert!(!d.is_suspected(0));
    }

    #[test]
    fn live_replica_past_timeout_stays_suspected_not_confirmed() {
        let mut d = det();
        assert_eq!(d.tick(0, 200, false), Verdict::Suspect);
        assert_eq!(d.tick(0, 500, false), Verdict::None, "no false kill");
        assert!(d.is_suspected(0));
        // a catch-up heartbeat heals it even from deep lag
        d.emit(0, 500, 0);
        assert_eq!(d.tick(0, 550, false), Verdict::Unsuspect);
    }

    #[test]
    fn joiners_start_with_a_fresh_synthetic_heartbeat() {
        let mut d = det();
        d.ensure(3, 1_000);
        assert_eq!(d.tick(2, 1_050, false), Verdict::None);
        assert_eq!(d.tick(2, 1_200, false), Verdict::Suspect, "then ages");
    }

    #[test]
    fn fold_takes_the_freshest_arrival() {
        let mut d = det();
        d.emit(0, 100, 300); // arrives 400
        d.emit(0, 200, 10); // arrives 210
        assert_eq!(d.tick(0, 450, true), Verdict::None, "last_hb = 400");
        assert_eq!(d.tick(0, 750, true), Verdict::Confirm, "age 350 >= 300");
    }
}
