//! The serving loop: policy-agnostic event loop that drives any
//! [`Policy`] against any [`DecodeEngine`] under any [`Clock`].
//!
//! This is the rust analogue of the paper's FastLLM integration: a
//! request buffer fed by arrivals, a scheduler invoked at iteration
//! boundaries, and a decode loop that executes the scheduler's steps.
//! Arrival/completion events are delivered between engine steps —
//! iteration-level interruption, exactly the granularity the paper's
//! event queue (Alg. 4) operates at.
//!
//! Two ways to drive a server (see DESIGN.md "Layers"):
//!   * [`Server::run`] — the single-device path: the whole workload is
//!     known up front and the loop runs to a horizon;
//!   * [`Server::run_until`] + [`Server::push_arrival`] +
//!     [`Server::finish`] — the incremental path used by the cluster
//!     layer (`cluster::Router`), which feeds arrivals one routing
//!     decision at a time while stepping each replica's virtual clock.
//!     Both paths execute the identical scheduler/engine code.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::pool::TaskPool;
use crate::coordinator::scheduler::{Policy, Step};
use crate::coordinator::task::{Residency, Task, TaskId, TaskState};
use crate::engine::clock::Clock;
use crate::engine::memory::{KvCacheModel, MemoryStats};
use crate::engine::{DecodeEngine, StepOutcome};
use crate::util::Micros;

/// Outcome of a full serving run.
#[derive(Debug)]
pub struct RunReport {
    /// Every task, with its complete timing record.
    pub tasks: Vec<Task>,
    /// Total engine steps executed (prefill + decode).
    pub steps: u64,
    /// Decode iterations executed.
    pub decode_steps: u64,
    /// Prefill passes executed.
    pub prefill_steps: u64,
    /// Scheduling decisions the policy reports (full Alg. 4
    /// reschedules for SLICE; zero for policies that don't count) —
    /// the numerator of the scale sweep's decisions-per-second.
    pub decisions: u64,
    /// Reschedules the policy proved unnecessary and skipped (SLICE's
    /// arrival-boundary precondition, DESIGN.md "Control-plane
    /// incrementality"); `decisions + decisions_skipped` equals the
    /// decision count of a skip-disabled run exactly.
    pub decisions_skipped: u64,
    /// Tasks shed mid-run because their KV footprint could never fit
    /// the device's capacity (each is terminal, unserved, and counts
    /// as an SLO violation — see [`Task::shed`]).
    pub shed: u64,
    /// Time of the last event processed.
    pub end_time: Micros,
    /// Policy name (for reports).
    pub policy: &'static str,
    /// KV-cache accounting: resident peak plus swap/recompute/handoff
    /// transition counters (all zero except the peak unless the run was
    /// capacity-constrained).
    pub memory: MemoryStats,
}

/// Streaming token callback: (task, token byte, timestamp). This is the
/// paper's `tokenBuf` (Alg. 1): tokens are delivered to the client as
/// they are generated, not at completion.
pub type TokenSink = Box<dyn FnMut(TaskId, u8, Micros) + Send>;

/// The serving loop.
pub struct Server<C: Clock> {
    pool: TaskPool,
    policy: Box<dyn Policy>,
    engine: Box<dyn DecodeEngine>,
    clock: C,
    /// Future arrivals, sorted by arrival time.
    arrivals: VecDeque<Task>,
    /// Delivered-but-unfinished task ids, ascending (the live set).
    /// Maintained at delivery/completion/extraction so per-step scans
    /// (and the cluster layer's load/headroom signals) touch only live
    /// work instead of every task the pool ever accepted.
    live: Vec<TaskId>,
    /// Unfinished tasks whose KV cache is resident, ascending.
    /// Maintained at every residency transition so eviction victim
    /// search is O(resident) instead of O(pool).
    resident: Vec<TaskId>,
    steps: u64,
    decode_steps: u64,
    prefill_steps: u64,
    shed: u64,
    token_sink: Option<TokenSink>,
}

/// Insert `id` into a sorted id index (no-op if present).
fn index_insert(index: &mut Vec<TaskId>, id: TaskId) {
    if let Err(at) = index.binary_search(&id) {
        index.insert(at, id);
    }
}

/// Remove `id` from a sorted id index (no-op if absent).
fn index_remove(index: &mut Vec<TaskId>, id: TaskId) {
    if let Ok(at) = index.binary_search(&id) {
        index.remove(at);
    }
}

/// Bring one swapped batch member's cache back on-device and return the
/// cost to charge before the pass. A task with no pending fee *and* no
/// slot in this device's model is a zero-fee migrated-in cache: it
/// arrived over the link already paid for, so it is adopted free
/// (`insert`). Everything else — a pending handoff fee, or a slot this
/// device evicted locally — pays `restore`'s priced transition. One
/// code path for constrained and unconstrained destinations, so a
/// zero-fee migrated-in task is priced identically on both (the PR 4
/// carried-forward fix; pinned by `zero_fee_handoff_restores_free_*`
/// tests below).
fn restore_swapped(kv: &mut KvCacheModel, id: TaskId, tokens: u32, pending: Micros) -> Micros {
    if pending == 0 && kv.tokens_of(id).is_none() {
        kv.insert(id, tokens); // free-link adoption
        0
    } else {
        kv.restore(id, tokens, pending)
    }
}

impl<C: Clock> Server<C> {
    /// Build a server over a pre-generated workload. Tasks must be sorted
    /// by arrival time and have dense ids in arrival order.
    pub fn new(
        workload: Vec<Task>,
        policy: Box<dyn Policy>,
        engine: Box<dyn DecodeEngine>,
        clock: C,
    ) -> Self {
        assert!(
            workload.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "workload must be sorted by arrival"
        );
        Server {
            pool: TaskPool::new(),
            policy,
            engine,
            clock,
            arrivals: workload.into(),
            live: Vec::new(),
            resident: Vec::new(),
            steps: 0,
            decode_steps: 0,
            prefill_steps: 0,
            shed: 0,
            token_sink: None,
        }
    }

    /// Attach a streaming token sink (the paper's `tokenBuf`): called
    /// once per generated token, in generation order.
    pub fn with_token_sink(mut self, sink: TokenSink) -> Self {
        self.token_sink = Some(sink);
        self
    }

    /// Current time on this server's clock.
    pub fn now(&self) -> Micros {
        self.clock.now()
    }

    /// The task pool (read-only observability for routers/tests).
    pub fn pool(&self) -> &TaskPool {
        &self.pool
    }

    /// Ids of delivered, unfinished tasks, ascending — exactly the
    /// tasks `pool().iter().filter(|t| !t.is_finished())` would yield,
    /// without scanning every task the pool ever accepted. Routers read
    /// their load/headroom signals through this (the per-decision hot
    /// path at cluster scale).
    pub fn live_ids(&self) -> &[TaskId] {
        &self.live
    }

    /// Arrivals that have been pushed/loaded but not yet delivered to
    /// the policy (they still count toward a replica's future load).
    pub fn pending_arrivals(&self) -> impl Iterator<Item = &Task> {
        self.arrivals.iter()
    }

    /// Earliest time at which [`Server::run_until`] would do real work:
    /// `now` while any delivered task is unfinished (the serving loop
    /// has live work this instant), else the first pending arrival's
    /// time, else `None` (fully idle — running the loop would only move
    /// the clock). This is the cluster event engine's next-event query
    /// (DESIGN.md "Event-driven cluster engine").
    pub fn next_event_time(&self) -> Option<Micros> {
        if !self.live.is_empty() {
            return Some(self.clock.now());
        }
        self.arrivals.front().map(|t| t.arrival)
    }

    /// Move the clock to `t` (monotonic — never backwards) without
    /// running the serving loop. Only meaningful while
    /// [`Server::next_event_time`] is `None`: an idle server's
    /// `run_until` delivers nothing and steps nothing, so the clock
    /// move is the entire effect.
    pub fn sync_clock(&mut self, t: Micros) {
        debug_assert!(
            self.next_event_time().is_none(),
            "sync_clock would skip real serving work"
        );
        self.clock.advance_to(t);
    }

    /// Inject one externally routed arrival (the cluster path). Arrivals
    /// must be pushed in non-decreasing arrival-time order and carry the
    /// pool's next dense id, exactly like a pre-generated workload.
    pub fn push_arrival(&mut self, task: Task) {
        assert!(
            self.arrivals.back().map_or(true, |b| b.arrival <= task.arrival),
            "arrivals must be pushed in time order"
        );
        self.arrivals.push_back(task);
    }

    /// Withdraw every pushed-but-undelivered arrival, in queue order
    /// (the cluster migration path). The scheduler never saw these
    /// tasks — they were waiting between iteration boundaries — so
    /// removing them cannot perturb policy state; the caller re-places
    /// them (possibly on another replica) and re-pushes survivors.
    pub fn withdraw_pending(&mut self) -> Vec<Task> {
        self.arrivals.drain(..).collect()
    }

    /// Deliver all arrivals due at or before `now`.
    fn deliver_arrivals(&mut self, now: Micros) {
        let mut ids: Vec<TaskId> = Vec::new();
        while self.arrivals.front().map_or(false, |t| t.arrival <= now) {
            let t = self.arrivals.pop_front().unwrap();
            ids.push(t.id);
            // dense pool ids arrive ascending, so this is a push; a
            // migrated-in task can arrive with its cache already marked
            // in transit, but never resident
            index_insert(&mut self.live, t.id);
            debug_assert!(t.residency != Residency::Resident);
            self.pool.insert(t);
        }
        if !ids.is_empty() {
            self.policy.on_arrival(&mut self.pool, &ids, now);
        }
    }

    /// Apply an engine step outcome: record tokens, detect completions.
    fn apply_outcome(&mut self, outcome: StepOutcome, now: Micros) {
        let mut completed: Vec<TaskId> = Vec::new();
        for tok in outcome.tokens {
            let t = self.pool.get_mut(tok.task);
            if t.is_finished() {
                continue;
            }
            t.generated.push(tok.token);
            t.on_token(now);
            if let Some(kv) = self.engine.kv_model_mut() {
                kv.note_token(tok.task);
            }
            if let Some(sink) = &mut self.token_sink {
                sink(tok.task, tok.token, now);
            }
            let t = self.pool.get_mut(tok.task);
            if tok.eos && !t.is_finished() {
                t.finish(now);
            }
            if t.is_finished() {
                completed.push(tok.task);
            }
        }
        if !completed.is_empty() {
            for &id in &completed {
                self.engine.release(id);
                self.pool.get_mut(id).residency = Residency::None;
                index_remove(&mut self.live, id);
                index_remove(&mut self.resident, id);
            }
            self.policy.on_completion(&mut self.pool, &completed, now);
        }
    }

    /// True when the engine models a finite KV capacity the loop must
    /// enforce.
    fn memory_constrained(&self) -> bool {
        self.engine.kv_model().is_some_and(|m| m.constrained())
    }

    /// The next eviction victim: a resident, unfinished task outside
    /// `protected`. Deterministic order — paused (descheduled) tasks
    /// first, then anything else, ascending id — so constrained runs
    /// reproduce bit-for-bit. The search walks the resident index (kept
    /// at every residency transition) instead of the whole pool, so one
    /// eviction is O(resident) even with thousands of tasks queued.
    fn pick_victim(&self, protected: &[TaskId]) -> Option<TaskId> {
        self.resident
            .iter()
            .map(|&id| self.pool.get(id))
            .filter(|t| {
                // index members are resident and unfinished by
                // construction; the original predicate stays as a
                // belt-and-braces filter
                t.residency == Residency::Resident
                    && !t.is_finished()
                    && !protected.contains(&t.id)
            })
            .map(|t| (u8::from(t.state != TaskState::Paused), t.id))
            .min()
            .map(|(_, id)| id)
    }

    /// Evict one victim outside `protected`, charging the swap-out cost
    /// and updating the task's residency record. Returns the cost, or
    /// `None` when nothing outside `protected` is resident.
    fn evict_one(&mut self, protected: &[TaskId]) -> Option<Micros> {
        let victim = self.pick_victim(protected)?;
        let cost = self
            .engine
            .kv_model_mut()
            .expect("eviction only runs with a kv model")
            .swap_out(victim);
        let t = self.pool.get_mut(victim);
        t.residency = Residency::Swapped;
        t.swap_outs += 1;
        index_remove(&mut self.resident, victim);
        Some(cost)
    }

    /// Terminate a delivered task this device can never serve (its KV
    /// footprint exceeds the whole capacity, so no eviction sequence
    /// helps). The task keeps its partial record but becomes terminal:
    /// `Finished` state with [`Task::shed`] set and no completion
    /// timestamp, so it leaves the live indexes and counts as an SLO
    /// violation in every report. The policy sees a completion event —
    /// capacity is freed and SLICE reschedules — exactly as it does
    /// when a task is extracted for migration.
    fn shed_task(&mut self, id: TaskId, now: Micros) {
        {
            let t = self.pool.get_mut(id);
            debug_assert!(!t.is_finished() && !t.migrated_away);
            t.shed = true;
            t.state = TaskState::Finished;
            t.residency = Residency::None;
            t.pending_restore = 0;
        }
        index_remove(&mut self.live, id);
        index_remove(&mut self.resident, id);
        self.engine.release(id);
        self.shed += 1;
        self.policy.on_completion(&mut self.pool, &[id], now);
    }

    /// Make room for a prompt of `task` before prefill: evict resident
    /// tasks (paused first) until the prompt's blocks fit. Returns the
    /// total transition cost to charge before the prefill pass, or
    /// `None` when the prompt alone exceeds the device capacity and the
    /// task was shed (a memory-oblivious policy can schedule such a
    /// prefill; the run must survive it).
    fn prepare_prefill(&mut self, task: TaskId) -> Option<Micros> {
        if !self.memory_constrained() {
            return Some(0);
        }
        let kv = self.engine.kv_model().expect("constrained model");
        let cap = kv.capacity().expect("constrained model");
        let need = kv.bytes_for(self.pool.get(task).prompt_len + 1);
        if need > cap {
            let now = self.clock.now();
            self.shed_task(task, now);
            return None;
        }
        let mut cost = 0;
        while self.engine.kv_model().expect("kv").occupied_bytes() + need > cap {
            match self.evict_one(&[task]) {
                Some(c) => cost += c,
                None => break, // only finished remnants left; release freed them
            }
        }
        Some(cost)
    }

    /// Admit a decode batch against the KV capacity: trim the batch to
    /// the prefix whose post-step footprint fits, evict resident
    /// non-batch tasks until it does, and restore (swap-in / recompute /
    /// pay the handoff fee of) every swapped batch member. A batch head
    /// whose footprint alone exceeds the whole capacity can never
    /// decode again — it is shed (counted SLO-violated) and the rest of
    /// the batch retried, so a memory-oblivious policy cannot kill the
    /// run by growing one task past the device. Returns the surviving
    /// batch (possibly empty) and the total transition cost to charge
    /// before the decode pass.
    fn prepare_decode(&mut self, tasks: Vec<TaskId>) -> (Vec<TaskId>, Micros) {
        if !self.memory_constrained() {
            // even an unconstrained destination owes a migrated-in
            // task's KV-handoff fee before it can decode (the only way
            // residency is Swapped on an unconstrained device)
            let mut cost = 0;
            for &id in &tasks {
                if self.pool.get(id).residency == Residency::Swapped {
                    let (tokens, pending) = {
                        let t = self.pool.get(id);
                        (t.seq_len(), t.pending_restore)
                    };
                    match self.engine.kv_model_mut() {
                        Some(kv) => cost += restore_swapped(kv, id, tokens, pending),
                        None => cost += pending,
                    }
                    let t = self.pool.get_mut(id);
                    t.residency = Residency::Resident;
                    t.pending_restore = 0;
                    t.swap_ins += 1;
                    index_insert(&mut self.resident, id);
                }
            }
            return (tasks, cost);
        }
        let cap = self
            .engine
            .kv_model()
            .and_then(|m| m.capacity())
            .expect("constrained model");
        // post-step footprint of the batch prefix that fits; the kept
        // set is always a prefix, so the incoming buffer is truncated
        // in place and stays recyclable (no per-step allocation). A
        // head that fits nothing is shed and the scan restarted on the
        // remainder (the rare outgrown-the-device path).
        let mut kept = tasks;
        let mut need: u64;
        loop {
            need = 0;
            let mut keep_len = 0usize;
            {
                let kv = self.engine.kv_model().expect("kv");
                for &id in &kept {
                    let b = kv.bytes_for(self.pool.get(id).seq_len() + 1);
                    if need + b <= cap {
                        need += b;
                        keep_len += 1;
                    } else {
                        break;
                    }
                }
            }
            if keep_len > 0 {
                kept.truncate(keep_len);
                break;
            }
            match kept.first().copied() {
                Some(head) => {
                    let now = self.clock.now();
                    self.shed_task(head, now);
                    kept.remove(0);
                }
                None => return (kept, 0),
            }
        }
        let mut cost = 0;
        while self.engine.kv_model().expect("kv").resident_outside(&kept) + need > cap {
            match self.evict_one(&kept) {
                Some(c) => cost += c,
                None => break,
            }
        }
        for &id in &kept {
            if self.pool.get(id).residency != Residency::Resident {
                let (tokens, pending) = {
                    let t = self.pool.get(id);
                    (t.seq_len(), t.pending_restore)
                };
                let kv = self.engine.kv_model_mut().expect("kv");
                cost += restore_swapped(kv, id, tokens, pending);
                let t = self.pool.get_mut(id);
                t.residency = Residency::Resident;
                t.pending_restore = 0;
                t.swap_ins += 1;
                index_insert(&mut self.resident, id);
            }
        }
        (kept, cost)
    }

    /// Execute one non-idle step: drive the engine, advance the clock,
    /// and apply the outcome. Shared by [`Server::run`] and
    /// [`Server::run_until`] so both paths step identically.
    fn execute_step(&mut self, step: Step) -> Result<()> {
        match step {
            Step::Idle => unreachable!("execute_step called with Idle"),
            Step::Prefill { task } => {
                // capacity enforcement: evictions are charged *before*
                // the prefill pass, so token timestamps include them
                let Some(mem_cost) = self.prepare_prefill(task) else {
                    // the prompt can never fit: the task was shed, no
                    // engine pass runs, and no step is counted
                    return Ok(());
                };
                if mem_cost > 0 {
                    self.clock.advance(mem_cost);
                }
                self.steps += 1;
                self.prefill_steps += 1;
                let outcome = self.engine.prefill(&self.pool, task)?;
                self.clock.advance(outcome.duration);
                let end = self.clock.now();
                let prompt_len = {
                    let t = self.pool.get_mut(task);
                    t.state = TaskState::Running;
                    t.prefill_end = Some(end);
                    t.residency = Residency::Resident;
                    t.prompt_len
                };
                index_insert(&mut self.resident, task);
                if let Some(kv) = self.engine.kv_model_mut() {
                    kv.insert(task, prompt_len);
                }
                self.apply_outcome(outcome, end);
            }
            Step::Decode { tasks } => {
                assert!(!tasks.is_empty(), "policy returned empty decode batch");
                // swap-in / recompute / handoff fees and any forced
                // evictions are paid before the forward pass (pause and
                // resume are no longer free under a finite capacity)
                let (tasks, mem_cost) = self.prepare_decode(tasks);
                if tasks.is_empty() {
                    // every batch member was shed: nothing to run this
                    // iteration; hand the buffer back and re-decide
                    self.policy.recycle_batch(tasks);
                    return Ok(());
                }
                if mem_cost > 0 {
                    self.clock.advance(mem_cost);
                }
                self.steps += 1;
                self.decode_steps += 1;
                let outcome = self.engine.decode(&self.pool, &tasks)?;
                self.clock.advance(outcome.duration);
                let end = self.clock.now();
                self.apply_outcome(outcome, end);
                // hand the batch buffer back so the policy's next
                // column scan reuses the allocation
                self.policy.recycle_batch(tasks);
            }
        }
        Ok(())
    }

    /// Run until all tasks finish or `horizon` is reached. Tasks still
    /// unfinished at the horizon keep their partial records (and count
    /// as SLO violations in the metrics).
    pub fn run(mut self, horizon: Micros) -> Result<RunReport> {
        loop {
            let now = self.clock.now();
            if now >= horizon {
                break;
            }
            self.deliver_arrivals(now);

            let step = self.policy.next_step(&mut self.pool, now);
            match step {
                Step::Idle => match self.arrivals.front().map(|t| t.arrival) {
                    Some(next) => self.clock.advance_to(next.min(horizon)),
                    None => break, // nothing running, nothing arriving
                },
                step => self.execute_step(step)?,
            }
        }
        Ok(self.finish())
    }

    /// Drive the server until its clock reaches `until`, then return
    /// control (the cluster path). An engine step that straddles `until`
    /// is executed to completion — arrivals pushed afterwards are
    /// delivered at the next iteration boundary, exactly as an arrival
    /// during an in-flight forward pass would be on a single device.
    /// When idle with no pending arrivals, the clock jumps to `until`.
    pub fn run_until(&mut self, until: Micros) -> Result<()> {
        loop {
            let now = self.clock.now();
            if now >= until {
                return Ok(());
            }
            self.deliver_arrivals(now);

            let step = self.policy.next_step(&mut self.pool, now);
            match step {
                Step::Idle => {
                    let next = self.arrivals.front().map_or(until, |t| t.arrival.min(until));
                    self.clock.advance_to(next);
                }
                step => self.execute_step(step)?,
            }
        }
    }

    /// Extract one delivered, unfinished task for migration to another
    /// replica (the cluster KV-handoff path). The pool keeps a husk —
    /// marked `migrated_away`, excluded from scheduling and reports —
    /// so local ids stay dense; the returned snapshot carries the full
    /// timing record forward. The policy is told the task left service
    /// (a completion event), which frees its capacity and, for SLICE,
    /// triggers the Alg. 4 reschedule a departure implies.
    pub fn extract_task(&mut self, id: TaskId, now: Micros) -> Task {
        let snapshot = {
            let t = self.pool.get_mut(id);
            assert!(
                !t.is_finished() && !t.migrated_away,
                "extracting task {id} twice or after completion"
            );
            let snap = t.clone();
            t.migrated_away = true;
            t.state = TaskState::Finished;
            t.residency = Residency::None;
            snap
        };
        index_remove(&mut self.live, id);
        index_remove(&mut self.resident, id);
        self.engine.release(id);
        self.policy.on_completion(&mut self.pool, &[id], now);
        snapshot
    }

    /// Consume the server and build the final report at the current
    /// clock (the terminal step of the incremental path).
    pub fn finish(self) -> RunReport {
        let memory = self.engine.kv_model().map(|m| m.stats()).unwrap_or_default();
        RunReport {
            policy: self.policy.name(),
            end_time: self.clock.now(),
            decisions: self.policy.decisions(),
            decisions_skipped: self.policy.decisions_skipped(),
            tasks: self.pool.into_tasks(),
            steps: self.steps,
            decode_steps: self.decode_steps,
            prefill_steps: self.prefill_steps,
            shed: self.shed,
            memory,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::orca::OrcaPolicy;
    use crate::coordinator::slice::SlicePolicy;
    use crate::coordinator::task::TaskClass;
    use crate::engine::clock::VirtualClock;
    use crate::engine::latency::LatencyModel;
    use crate::engine::sim::SimEngine;
    use crate::util::secs;

    fn mk_task(id: TaskId, class: TaskClass, arrival: Micros, out: u32) -> Task {
        let u = if class.is_real_time() { 100.0 } else { 1.0 };
        Task::new(id, class, arrival, 16, out, u)
    }

    #[test]
    fn single_task_completes_under_orca() {
        let workload = vec![mk_task(0, TaskClass::Voice, 0, 10)];
        let server = Server::new(
            workload,
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        let report = server.run(secs(60.0)).unwrap();
        let t = &report.tasks[0];
        assert!(t.is_finished());
        assert_eq!(t.tokens_generated, 10);
        // 1 prefill + 9 decodes
        assert_eq!(report.prefill_steps, 1);
        assert_eq!(report.decode_steps, 9);
        // TPOT under Orca solo = l(1) = 18ms < 125ms SLO
        assert!(t.slo_met());
    }

    #[test]
    fn single_task_completes_under_slice() {
        let workload = vec![mk_task(0, TaskClass::RealTime, 0, 10)];
        let server = Server::new(
            workload,
            Box::new(SlicePolicy::with_defaults(LatencyModel::paper_calibrated())),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        let report = server.run(secs(60.0)).unwrap();
        let t = &report.tasks[0];
        assert!(t.is_finished());
        assert!(t.slo_met(), "completion={:?}", t.completion_time());
    }

    #[test]
    fn arrivals_delivered_in_time_order() {
        let workload = vec![
            mk_task(0, TaskClass::Voice, 0, 5),
            mk_task(1, TaskClass::Voice, secs(0.5), 5),
            mk_task(2, TaskClass::Voice, secs(1.0), 5),
        ];
        let server = Server::new(
            workload,
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        let report = server.run(secs(60.0)).unwrap();
        assert!(report.tasks.iter().all(|t| t.is_finished()));
        // later arrivals must not get tokens before their arrival
        for t in &report.tasks {
            assert!(t.first_token.unwrap() >= t.arrival);
        }
    }

    #[test]
    fn horizon_cuts_off_unfinished_tasks() {
        let workload = vec![mk_task(0, TaskClass::Voice, 0, 10_000)];
        let server = Server::new(
            workload,
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        let report = server.run(secs(2.0)).unwrap();
        let t = &report.tasks[0];
        assert!(!t.is_finished());
        assert!(!t.slo_met());
        assert!(report.end_time >= secs(2.0));
    }

    #[test]
    fn incremental_path_matches_run() {
        // Feeding the same workload through push_arrival + run_until
        // must reproduce Server::run exactly (the cluster contract).
        let workload = vec![
            mk_task(0, TaskClass::RealTime, 0, 10),
            mk_task(1, TaskClass::Voice, secs(0.2), 20),
            mk_task(2, TaskClass::TextQa, secs(0.9), 15),
        ];
        let horizon = secs(60.0);
        let baseline = Server::new(
            workload.clone(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        )
        .run(horizon)
        .unwrap();

        let mut incremental = Server::new(
            Vec::new(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        for task in workload {
            incremental.run_until(task.arrival).unwrap();
            incremental.push_arrival(task);
        }
        incremental.run_until(horizon).unwrap();
        let report = incremental.finish();

        assert_eq!(report.steps, baseline.steps);
        for (a, b) in baseline.tasks.iter().zip(&report.tasks) {
            assert_eq!(a.first_token, b.first_token);
            assert_eq!(a.completion, b.completion);
            assert_eq!(a.tokens_generated, b.tokens_generated);
        }
    }

    #[test]
    fn live_ids_track_delivery_completion_and_extraction() {
        let mut s = Server::new(
            Vec::new(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        assert!(s.live_ids().is_empty());
        s.push_arrival(mk_task(0, TaskClass::Voice, 0, 5));
        s.push_arrival(mk_task(1, TaskClass::Voice, 0, 500));
        s.push_arrival(mk_task(2, TaskClass::Voice, 0, 500));
        s.run_until(secs(2.0)).unwrap(); // task 0 (5 tokens) finishes
        assert_eq!(s.live_ids(), &[1, 2], "finished task left the live set");
        // the live set always mirrors the pool's unfinished filter
        let expected: Vec<TaskId> = s
            .pool()
            .iter()
            .filter(|t| !t.is_finished())
            .map(|t| t.id)
            .collect();
        assert_eq!(s.live_ids(), &expected[..]);
        let now = s.now();
        let _ = s.extract_task(1, now);
        assert_eq!(s.live_ids(), &[2], "extracted husk left the live set");
        s.run_until(secs(120.0)).unwrap();
        assert!(s.live_ids().is_empty(), "drained server has no live work");
    }

    #[test]
    fn run_until_idle_jumps_to_target() {
        let mut s = Server::new(
            Vec::new(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        s.run_until(secs(5.0)).unwrap();
        assert_eq!(s.now(), secs(5.0));
        assert_eq!(s.pool().len(), 0);
        assert_eq!(s.pending_arrivals().count(), 0);
    }

    #[test]
    fn withdraw_pending_drains_undelivered_only() {
        let mut s = Server::new(
            Vec::new(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        s.push_arrival(mk_task(0, TaskClass::Voice, 0, 5));
        s.run_until(secs(1.0)).unwrap(); // task 0 delivered (and served)
        s.push_arrival(mk_task(1, TaskClass::Voice, secs(2.0), 5));
        s.push_arrival(mk_task(2, TaskClass::Voice, secs(3.0), 5));
        let withdrawn = s.withdraw_pending();
        assert_eq!(withdrawn.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(s.pending_arrivals().count(), 0);
        assert_eq!(s.pool().len(), 1, "delivered task not withdrawn");
        // the server keeps running normally afterwards
        s.run_until(secs(5.0)).unwrap();
        assert_eq!(s.now(), secs(5.0));
    }

    fn constrained_engine(capacity: u64) -> Box<SimEngine> {
        use crate::engine::memory::{KvCacheModel, MemoryConfig};
        let lat = LatencyModel::paper_calibrated();
        let kv = KvCacheModel::new(
            MemoryConfig { kv_capacity: Some(capacity), ..MemoryConfig::default() },
            Some(capacity),
            lat.clone(),
        );
        Box::new(SimEngine::new(lat, 8192).with_memory(kv))
    }

    #[test]
    fn memory_accounting_tracks_peak_without_charges_when_roomy() {
        let workload = vec![mk_task(0, TaskClass::Voice, 0, 10)];
        let server = Server::new(
            workload,
            Box::new(OrcaPolicy::new(32)),
            constrained_engine(64 * 1024 * 1024),
            VirtualClock::new(),
        );
        let report = server.run(secs(60.0)).unwrap();
        assert!(report.memory.peak_kv_bytes > 0);
        assert_eq!(report.memory.swap_outs, 0);
        assert_eq!(report.memory.swap_delay, 0);
        // completion released the cache: the peak is the only residue
        assert!(report.tasks[0].is_finished());
    }

    #[test]
    fn tight_capacity_charges_swap_latency_on_the_clock() {
        // two long voice tasks under a capacity that holds only one
        // cache: the serving loop must evict/restore, and the paid
        // transitions show up as engine-time (slower completion), not
        // as free preemption
        let mk = || {
            vec![
                mk_task(0, TaskClass::Voice, 0, 150),
                mk_task(1, TaskClass::Voice, 0, 150),
            ]
        };
        let free = Server::new(
            mk(),
            Box::new(SlicePolicy::with_defaults(LatencyModel::paper_calibrated())),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        )
        .run(secs(600.0))
        .unwrap();
        // each task's cache grows to ~6 MiB; 6 MiB holds one, not two
        let tight = Server::new(
            mk(),
            Box::new(SlicePolicy::with_defaults(LatencyModel::paper_calibrated())),
            constrained_engine(6 * 1024 * 1024),
            VirtualClock::new(),
        )
        .run(secs(600.0))
        .unwrap();
        assert!(tight.memory.swap_outs > 0, "tight cell must evict");
        assert!(tight.memory.swap_delay > 0, "transitions are not free");
        assert!(tight.memory.peak_kv_bytes <= 6 * 1024 * 1024);
        let done = |r: &RunReport| r.tasks.iter().filter_map(|t| t.completion).max();
        assert!(
            done(&tight).unwrap() > done(&free).unwrap(),
            "swap latency must appear in task timings"
        );
        let swapped: u32 = tight.tasks.iter().map(|t| t.swap_outs).sum();
        assert!(swapped > 0, "per-task swap counters recorded");
    }

    #[test]
    fn oversized_prompt_is_shed_not_fatal() {
        // a prompt whose footprint exceeds the whole KV capacity can
        // never prefill; the run must shed it and keep serving, not
        // abort with an error (the PR 4 carried-forward fix)
        let workload = vec![
            Task::new(0, TaskClass::Voice, 0, 1000, 10, 1.0), // ~33 MiB prompt
            mk_task(1, TaskClass::Voice, 0, 10),
        ];
        let report = Server::new(
            workload,
            Box::new(OrcaPolicy::new(32)),
            constrained_engine(2 * 1024 * 1024),
            VirtualClock::new(),
        )
        .run(secs(60.0))
        .unwrap();
        assert_eq!(report.shed, 1);
        let t0 = &report.tasks[0];
        assert!(t0.shed && t0.is_finished() && !t0.slo_met());
        assert_eq!(t0.tokens_generated, 0, "shed before any engine pass");
        assert_eq!(t0.completion_time(), None, "shed is not completion");
        let t1 = &report.tasks[1];
        assert!(t1.is_finished() && !t1.shed, "the fleet keeps serving");
    }

    #[test]
    fn task_outgrowing_capacity_is_shed_mid_decode() {
        // a memory-oblivious policy grows one task's cache past the
        // device: once even a solo decode slot no longer fits, the
        // task is shed with its partial record and the run continues
        let workload = vec![mk_task(0, TaskClass::Voice, 0, 200)];
        // cap = 4 blocks of 16 tokens: prefill (16-token prompt) fits,
        // decode stops fitting once seq_len + 1 > 64
        let report = Server::new(
            workload,
            Box::new(OrcaPolicy::new(32)),
            constrained_engine(2 * 1024 * 1024),
            VirtualClock::new(),
        )
        .run(secs(600.0))
        .unwrap();
        assert_eq!(report.shed, 1);
        let t = &report.tasks[0];
        assert!(t.shed && t.is_finished() && !t.slo_met());
        assert_eq!(t.tokens_generated, 48, "partial record kept (64 - 16)");
        assert!(t.first_token.is_some());
        // the shed task's cache was released, not leaked
        assert!(report.memory.peak_kv_bytes <= 2 * 1024 * 1024);
    }

    #[test]
    fn extract_task_leaves_a_husk_and_returns_the_record() {
        let mut s = Server::new(
            Vec::new(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        s.push_arrival(mk_task(0, TaskClass::Voice, 0, 50));
        s.push_arrival(mk_task(1, TaskClass::Voice, 0, 50));
        s.run_until(secs(1.0)).unwrap();
        let now = s.now();
        let snap = s.extract_task(0, now);
        assert_eq!(snap.id, 0);
        assert!(snap.tokens_generated > 0, "partial record travels");
        assert!(!snap.migrated_away);
        // the husk is finished-for-scheduling and flagged
        assert!(s.pool().get(0).migrated_away);
        assert!(s.pool().get(0).is_finished());
        // the other task still runs to completion
        s.run_until(secs(60.0)).unwrap();
        let report = s.finish();
        let t1 = &report.tasks[1];
        assert!(t1.is_finished() && !t1.migrated_away);
    }

    #[test]
    #[should_panic]
    fn extract_finished_task_panics() {
        let mut s = Server::new(
            Vec::new(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        s.push_arrival(mk_task(0, TaskClass::Voice, 0, 5));
        s.run_until(secs(30.0)).unwrap(); // task 0 finished
        let now = s.now();
        let _ = s.extract_task(0, now);
    }

    #[test]
    #[should_panic]
    fn out_of_order_push_rejected() {
        let mut s = Server::new(
            Vec::new(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        s.push_arrival(mk_task(0, TaskClass::Voice, secs(2.0), 5));
        s.push_arrival(mk_task(1, TaskClass::Voice, secs(1.0), 5));
    }

    #[test]
    fn next_event_time_tracks_live_then_pending_then_idle() {
        let mut s = Server::new(
            Vec::new(),
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        assert_eq!(s.next_event_time(), None, "fresh server is idle");
        s.sync_clock(secs(1.0));
        assert_eq!(s.now(), secs(1.0), "idle clock moves without the loop");
        s.push_arrival(mk_task(0, TaskClass::Voice, secs(2.0), 500));
        assert_eq!(
            s.next_event_time(),
            Some(secs(2.0)),
            "pending arrival is the next event"
        );
        s.run_until(secs(2.5)).unwrap();
        assert_eq!(
            s.next_event_time(),
            Some(s.now()),
            "live unfinished work means the next event is now"
        );
        s.run_until(secs(60.0)).unwrap();
        assert_eq!(s.next_event_time(), None, "drained server is idle again");
    }

    #[test]
    fn zero_fee_handoff_restores_free_on_both_destination_kinds() {
        // The PR 4 carried-forward fix: a migrated-in cache with no
        // pending fee and no slot on the destination adopts for free —
        // identically whether the destination is capacity-constrained
        // or not.
        use crate::engine::memory::{KvCacheModel, MemoryConfig};
        let lat = LatencyModel::paper_calibrated();
        let cap = 64 * 1024 * 1024u64;
        let mut constrained = KvCacheModel::new(
            MemoryConfig { kv_capacity: Some(cap), ..MemoryConfig::default() },
            Some(cap),
            lat.clone(),
        );
        let mut unconstrained =
            KvCacheModel::new(MemoryConfig::default(), None, lat.clone());
        for kv in [&mut constrained, &mut unconstrained] {
            assert_eq!(
                restore_swapped(kv, 7, 81, 0),
                0,
                "zero-fee migrated-in cache is adopted free"
            );
            assert!(kv.is_resident(7));
            let stats = kv.stats();
            assert_eq!(stats.swap_ins, 0, "adoption is not a swap-in");
            assert_eq!(stats.handoff_restores, 0);
            assert_eq!(stats.swap_delay, 0, "no transition time charged");
        }

        // a *priced* handoff fee is charged verbatim on both kinds
        let mut constrained = KvCacheModel::new(
            MemoryConfig { kv_capacity: Some(cap), ..MemoryConfig::default() },
            Some(cap),
            lat.clone(),
        );
        let mut unconstrained = KvCacheModel::new(MemoryConfig::default(), None, lat);
        for kv in [&mut constrained, &mut unconstrained] {
            assert_eq!(restore_swapped(kv, 3, 81, 12_345), 12_345);
            let stats = kv.stats();
            assert_eq!(stats.handoff_restores, 1);
            assert_eq!(stats.swap_delay, 12_345);
        }

        // and a locally evicted slot (present, non-resident, zero fee)
        // still pays the constrained device's swap-in transition
        let mut kv = KvCacheModel::new(
            MemoryConfig { kv_capacity: Some(cap), ..MemoryConfig::default() },
            Some(cap),
            LatencyModel::paper_calibrated(),
        );
        kv.insert(9, 81);
        kv.swap_out(9);
        let cost = restore_swapped(&mut kv, 9, 81, 0);
        assert!(cost > 0, "local eviction round-trip is never free");
        assert_eq!(kv.stats().swap_ins, 1);
    }

    #[test]
    #[should_panic]
    fn unsorted_workload_rejected() {
        let workload = vec![
            mk_task(0, TaskClass::Voice, secs(1.0), 5),
            mk_task(1, TaskClass::Voice, 0, 5),
        ];
        let _ = Server::new(
            workload,
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
    }
}
