//! slice-serve CLI: launcher for serving, experiments and calibration.
//!
//! Subcommands (clap is unavailable offline, so parsing is hand-rolled):
//!   serve       — run a workload through one policy (sim or pjrt engine)
//!   cluster     — route a workload across a replica fleet (homogeneous
//!                 or a heterogeneous --fleet spec; round-robin,
//!                 least-loaded or SLO-aware; optional admission control
//!                 — queue-depth or Eq. 7 headroom — overload migration,
//!                 KV capacity limits and running-task KV handoff) and
//!                 report fleet + memory metrics
//!   experiment  — regenerate a paper table/figure (fig1|table2|fig7|
//!                 fig8|fig9|fig10|fig11|ablation|cluster|hetero|
//!                 memory|scale|all; scale = the 1k/4k/10k scheduler
//!                 throughput sweep, excluded from 'all')
//!   calibrate   — measure l(b) on the real PJRT engine and print a
//!                 machine-local latency model
//!   info        — print artifact/runtime information

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use slice_serve::cluster::{FleetSpec, LifecycleAction, LifecycleEvent, RoutingStrategy};
use slice_serve::config::{ClusterEngine, EngineKind, PolicyKind, ServeConfig};
#[cfg(feature = "pjrt")]
use slice_serve::coordinator::task::TaskClass;
use slice_serve::engine::clock::VirtualClock;
#[cfg(feature = "pjrt")]
use slice_serve::engine::clock::WallClock;
#[cfg(feature = "pjrt")]
use slice_serve::engine::latency::LatencyModel;
#[cfg(feature = "pjrt")]
use slice_serve::engine::pjrt::PjrtEngine;
#[cfg(feature = "pjrt")]
use slice_serve::engine::sampler::Sampler;
#[cfg(feature = "pjrt")]
use slice_serve::engine::DecodeEngine;
use slice_serve::experiments;
use slice_serve::metrics::report::{ms2, pct, secs2, Table};
use slice_serve::metrics::Attainment;
#[cfg(feature = "pjrt")]
use slice_serve::runtime::ModelRuntime;
use slice_serve::server::Server;
use slice_serve::util::json::Json;
use slice_serve::util::{logger, secs};
use slice_serve::workload::WorkloadSpec;

const USAGE: &str = "\
slice-serve — SLO-driven LLM inference scheduling (SLICE reproduction)

USAGE:
  slice-serve serve [--config <file>] [--policy slice|orca|fastserve]
                    [--engine sim|pjrt] [--artifacts <dir>]
                    [--kv-capacity <MiB>] [--swap-bandwidth <MB/s>]
                    [--preemption swap|recompute] [--memory-aware on|off]
                    [--rate <f>] [--rt-ratio <f>] [--n-tasks <n>] [--seed <n>]
                    [--trace <file>] [--save-trace <file>]
  slice-serve cluster [--config <file>] [--replicas <n>]
                    [--engine lockstep|event]  (cluster engine; lockstep = reference)
                    [--threads <n>]  (event-engine epoch workers; >1 implies --engine event)
                    [--fleet edge-mixed|<tier,tier,...>]  (tiers: standard|lite|nano)
                    [--strategy round-robin|least-loaded|slo-aware]
                    [--admission on|off|depth|headroom]
                    [--rt-queue <n>] [--nrt-queue <n>]
                    [--migration on|off] [--migrate-running on|off]
                    [--kv-capacity <MiB>] [--swap-bandwidth <MB/s>]
                    [--handoff-bandwidth <MB/s>] [--preemption swap|recompute]
                    [--memory-aware on|off]
                    [--crash-at <s[,s,...]>] [--churn <events/s>] [--churn-seed <n>]
                    [--autoscale on|off] [--boot-delay <s>]
                    [--fleet-min <n>] [--fleet-max <n>]
                    [--health on|off]  (elastic flags imply --engine event)
                    [--detect-delay <s>] [--heartbeat <s>] [--max-retries <n>]
                    (failure detection: crashes confirmed after
                     --detect-delay of missed heartbeats; 0 = oracle)
                    [--policy slice|orca|fastserve]
                    [--rate <f>] [--rt-ratio <f>] [--n-tasks <n>] [--seed <n>]
  slice-serve experiment <fig1|table2|fig7|fig8|fig9|fig10|fig11|ablation|
                    cluster|hetero|memory|scale|elastic|chaos|all> [--n-tasks <n>]
                    [--seed <n>] [--out <json>]
                    (scale: [--tasks <n>] runs one custom size instead of
                     the 1k/4k/10k default; [--replicas <n[,n,...]>] runs the
                     replica-width axis — event + lockstep engines over
                     homogeneous fleets, BENCH_6.json;
                     [--threads <n[,n,...]>] adds an event-engine worker
                     axis to the replica sweep — reports are bit-exact
                     across thread counts, BENCH_9.json; [--stream] runs the
                     constant-memory streaming axis — pull-based arrivals +
                     folded rejects up to 1M tasks, BENCH_8.json; excluded
                     from 'all')
                    (elastic: static/crash/autoscale variants of the
                     edge-mixed overload cell, BENCH_7.json; [--tasks <n>]
                     runs one custom size; excluded from 'all')
                    (chaos: detection delay x churn x retry policy over
                     the crash-at-overload cell, BENCH_10.json;
                     [--tasks <n>] runs one custom size; excluded from
                     'all')
  slice-serve calibrate --artifacts <dir> [--reps <n>]
  slice-serve info --artifacts <dir>
";

struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        // flags that take no value (presence is the signal)
        const BARE_FLAGS: &[&str] = &["stream"];
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if BARE_FLAGS.contains(&name) {
                    flags.push((name.to_string(), "on".to_string()));
                    i += 1;
                    continue;
                }
                let value = argv
                    .get(i + 1)
                    .with_context(|| format!("flag --{name} needs a value"))?
                    .clone();
                flags.push((name.to_string(), value));
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    fn flag_f64(&self, name: &str) -> Result<Option<f64>> {
        self.flag(name)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{name}: bad number")))
            .transpose()
    }

    fn flag_u64(&self, name: &str) -> Result<Option<u64>> {
        self.flag(name)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{name}: bad integer")))
            .transpose()
    }
}

fn build_config(args: &Args) -> Result<ServeConfig> {
    let mut cfg = match args.flag("config") {
        Some(path) => ServeConfig::from_file(&PathBuf::from(path))?,
        None => ServeConfig::default(),
    };
    if let Some(p) = args.flag("policy") {
        cfg.policy = PolicyKind::parse(p)?;
    }
    if let Some(e) = args.flag("engine") {
        match e {
            "sim" => cfg.engine = EngineKind::Sim,
            "pjrt" => {
                cfg.engine = EngineKind::Pjrt(PathBuf::from(
                    args.flag("artifacts").unwrap_or("artifacts"),
                ))
            }
            // cluster-engine spellings share the flag: the value sets
            // are disjoint, so `--engine event` can never mean pjrt
            "lockstep" | "router" | "event" | "orchestrator" => {
                cfg.cluster_engine = ClusterEngine::parse(e)?
            }
            other => bail!("unknown engine '{other}' (sim|pjrt|lockstep|event)"),
        };
    }
    if let Some(v) = args.flag_f64("rate")? {
        cfg.arrival_rate = v;
    }
    if let Some(v) = args.flag_f64("rt-ratio")? {
        cfg.rt_ratio = v;
    }
    if let Some(v) = args.flag_u64("n-tasks")? {
        cfg.n_tasks = v as usize;
    }
    if let Some(v) = args.flag_u64("seed")? {
        cfg.seed = v;
    }
    // [memory] knobs (shared by serve and cluster)
    if let Some(v) = args.flag_f64("kv-capacity")? {
        if v <= 0.0 {
            bail!("--kv-capacity must be positive MiB");
        }
        cfg.memory.kv_capacity = Some((v * 1024.0 * 1024.0) as u64);
    }
    if let Some(v) = args.flag_f64("swap-bandwidth")? {
        if v <= 0.0 {
            bail!("--swap-bandwidth must be positive MB/s");
        }
        cfg.memory.swap_bandwidth = (v * 1e6) as u64;
    }
    if let Some(v) = args.flag_f64("handoff-bandwidth")? {
        if v <= 0.0 {
            bail!("--handoff-bandwidth must be positive MB/s");
        }
        cfg.memory.handoff_bandwidth = (v * 1e6) as u64;
    }
    if let Some(v) = args.flag("preemption") {
        cfg.memory.mode = slice_serve::engine::memory::PreemptionMode::parse(v)?;
    }
    if let Some(v) = args.flag("memory-aware") {
        cfg.memory.aware = flag_switch("memory-aware", v)?;
    }
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let policy = experiments::build_policy(cfg.policy, &cfg);

    // workload source: --trace <file> replays a recorded trace; otherwise
    // generate from the config (and optionally --save-trace it).
    let load_workload = |edge: bool| -> Result<Vec<_>> {
        let workload = match args.flag("trace") {
            Some(path) => slice_serve::workload::trace::load(&PathBuf::from(path))?,
            None => {
                let spec = if edge {
                    WorkloadSpec::edge_mix(
                        cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed,
                    )
                } else {
                    WorkloadSpec::paper_mix(
                        cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed,
                    )
                };
                spec.generate()
            }
        };
        if let Some(path) = args.flag("save-trace") {
            slice_serve::workload::trace::save(&workload, &PathBuf::from(path))?;
            println!("saved workload trace to {path}");
        }
        Ok(workload)
    };

    let report = match &cfg.engine {
        EngineKind::Sim => {
            let workload = load_workload(false)?;
            let horizon = workload.last().map_or(0, |t| t.arrival) + secs(300.0);
            // the engine carries the configured memory model (an
            // unconstrained model by default — bit-identical timings)
            let engine = experiments::build_engine_for(
                &cfg,
                &experiments::standard_profile(&cfg),
            );
            Server::new(workload, policy, Box::new(engine), VirtualClock::new())
                .run(horizon)?
        }
        #[cfg(feature = "pjrt")]
        EngineKind::Pjrt(dir) => {
            // context-fitted workload with real prompt bytes
            let workload = load_workload(true)?;
            let horizon = workload.last().map_or(0, |t| t.arrival) + secs(300.0);
            let runtime = ModelRuntime::load(dir)?;
            let engine = PjrtEngine::new(runtime, Sampler::Greedy, cfg.seed);
            Server::new(workload, policy, Box::new(engine), WallClock::new()).run(horizon)?
        }
        #[cfg(not(feature = "pjrt"))]
        EngineKind::Pjrt(_) => bail!(
            "engine 'pjrt' is not compiled into this binary; rebuild with \
             `cargo build --release --features pjrt`"
        ),
    };

    let a = Attainment::compute(&report.tasks);
    println!(
        "policy={} tasks={} finished={} steps={} (prefill {}, decode {})",
        report.policy, a.n_tasks, a.n_finished, report.steps, report.prefill_steps,
        report.decode_steps
    );
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["overall SLO attainment".into(), pct(a.slo)]);
    t.row(vec!["real-time SLO attainment".into(), pct(a.rt_slo)]);
    t.row(vec!["non-RT SLO attainment".into(), pct(a.nrt_slo)]);
    t.row(vec!["mean completion (all)".into(), secs2(a.mean_completion_all)]);
    t.row(vec![
        "peak KV resident".into(),
        format!("{:.1} MiB", report.memory.peak_kv_bytes as f64 / (1024.0 * 1024.0)),
    ]);
    t.row(vec![
        "swaps out / in / recompute".into(),
        format!(
            "{} / {} / {}",
            report.memory.swap_outs, report.memory.swap_ins, report.memory.recomputes
        ),
    ]);
    println!("{}", t.render());
    Ok(())
}

/// Parse an on/off flag value.
fn flag_switch(name: &str, value: &str) -> Result<bool> {
    match value {
        "on" | "true" => Ok(true),
        "off" | "false" => Ok(false),
        other => bail!("--{name}: expected on|off, got '{other}'"),
    }
}

/// Route a synthetic workload across a replica fleet and report
/// fleet-wide plus per-replica SLO metrics.
fn cmd_cluster(args: &Args) -> Result<()> {
    let mut cfg = build_config(args)?;
    if args.flag("replicas").is_some() && args.flag("fleet").is_some() {
        bail!("--replicas and --fleet are mutually exclusive (a fleet spec fixes the width)");
    }
    if let Some(v) = args.flag_u64("replicas")? {
        if v < 1 {
            bail!("--replicas must be >= 1");
        }
        cfg.cluster_replicas = v as usize;
        cfg.cluster_fleet = None; // --replicas overrides a config-file fleet
    }
    if let Some(s) = args.flag("fleet") {
        let fleet = FleetSpec::preset(s)?.with_cycle_cap(cfg.cycle_cap);
        cfg.cluster_replicas = fleet.len();
        cfg.cluster_fleet = Some(fleet);
    }
    if let Some(s) = args.flag("strategy") {
        cfg.cluster_strategy = RoutingStrategy::parse(s)?;
    }
    let admission_flag = args.flag("admission");
    if let Some(s) = admission_flag {
        // on/off keep the configured signal; naming a mode selects it
        // and opts in
        match s {
            "depth" => {
                cfg.cluster_admission.enabled = true;
                cfg.cluster_admission.mode = slice_serve::cluster::AdmissionMode::QueueDepth;
            }
            "headroom" => {
                cfg.cluster_admission.enabled = true;
                cfg.cluster_admission.mode = slice_serve::cluster::AdmissionMode::Headroom;
            }
            other => cfg.cluster_admission.enabled = flag_switch("admission", other)?,
        }
    }
    // a bound flag implies admission unless --admission off was given —
    // a configured bound must never be a silent no-op
    let mut bound_set = false;
    if let Some(v) = args.flag_u64("rt-queue")? {
        if v < 1 {
            bail!("--rt-queue must be >= 1");
        }
        cfg.cluster_admission.rt_queue_bound = v as usize;
        bound_set = true;
    }
    if let Some(v) = args.flag_u64("nrt-queue")? {
        if v < 1 {
            bail!("--nrt-queue must be >= 1");
        }
        cfg.cluster_admission.nrt_queue_bound = v as usize;
        bound_set = true;
    }
    if bound_set && admission_flag.is_none() {
        cfg.cluster_admission.enabled = true;
    }
    let headroom_mode =
        cfg.cluster_admission.mode == slice_serve::cluster::AdmissionMode::Headroom;
    if bound_set && headroom_mode {
        // headroom admission never reads the depth bounds — a
        // configured bound must never be a silent no-op
        bail!("--rt-queue/--nrt-queue apply to depth admission; use --admission depth");
    }
    if let Some(s) = args.flag("migration") {
        cfg.cluster_migration = flag_switch("migration", s)?;
    }
    if let Some(s) = args.flag("migrate-running") {
        cfg.cluster_migrate_running = flag_switch("migrate-running", s)?;
        if cfg.cluster_migrate_running {
            // running handoff rides on the migration pass it extends:
            // enabling it always enables migration (same rule as the
            // [cluster] migrate_running config key)
            cfg.cluster_migration = true;
        }
    }
    // elastic-fleet flags (mirror the [cluster.lifecycle] section)
    if let Some(spec) = args.flag("crash-at") {
        for s in spec.split(',') {
            let t: f64 = s
                .trim()
                .parse()
                .with_context(|| format!("--crash-at: bad seconds '{s}'"))?;
            if t < 0.0 {
                bail!("--crash-at times must be non-negative seconds");
            }
            cfg.lifecycle.events.push(LifecycleEvent {
                time: secs(t),
                action: LifecycleAction::Crash,
                target: None,
            });
        }
        cfg.lifecycle.events.sort_by_key(|e| e.time);
    }
    if let Some(v) = args.flag_f64("churn")? {
        if v < 0.0 {
            bail!("--churn must be a non-negative event rate");
        }
        cfg.lifecycle.churn_rate = v;
    }
    if let Some(v) = args.flag_u64("churn-seed")? {
        cfg.lifecycle.seed = v;
    }
    if let Some(v) = args.flag_u64("fleet-min")? {
        if v < 1 {
            bail!("--fleet-min must be >= 1");
        }
        cfg.lifecycle.min_replicas = v as usize;
    }
    if let Some(v) = args.flag_u64("fleet-max")? {
        if v < 1 {
            bail!("--fleet-max must be >= 1");
        }
        cfg.lifecycle.max_replicas = v as usize;
    }
    if cfg.lifecycle.min_replicas > cfg.lifecycle.max_replicas {
        bail!("--fleet-min must not exceed --fleet-max");
    }
    if let Some(s) = args.flag("autoscale") {
        cfg.lifecycle.autoscaler.enabled = flag_switch("autoscale", s)?;
    }
    if let Some(v) = args.flag_f64("boot-delay")? {
        if v < 0.0 {
            bail!("--boot-delay must be non-negative seconds");
        }
        cfg.lifecycle.autoscaler.boot_delay = secs(v);
        // same rule as the [cluster.autoscaler] keys: a named knob opts
        // the autoscaler in unless --autoscale off is explicit
        if args.flag("autoscale").is_none() {
            cfg.lifecycle.autoscaler.enabled = true;
        }
    }
    if let Some(s) = args.flag("health") {
        cfg.lifecycle.health.enabled = flag_switch("health", s)?;
    }
    // failure-detector flags (mirror the [cluster.detector] section);
    // naming any knob opts the detector in — a configured knob is
    // never a silent no-op. --detect-delay 0 is the enabled-but-inert
    // oracle mode (crashes visible instantly, the pre-detector path).
    if let Some(v) = args.flag_f64("detect-delay")? {
        if v < 0.0 {
            bail!("--detect-delay must be non-negative seconds");
        }
        cfg.lifecycle.detector.suspicion_timeout = secs(v);
        cfg.lifecycle.detector.enabled = true;
    }
    if let Some(v) = args.flag_f64("heartbeat")? {
        if v <= 0.0 {
            bail!("--heartbeat must be positive seconds");
        }
        cfg.lifecycle.detector.heartbeat_interval = secs(v);
        cfg.lifecycle.detector.enabled = true;
    }
    if let Some(v) = args.flag_u64("max-retries")? {
        if v > u64::from(u32::MAX) {
            bail!("--max-retries must fit in [0, 2^32)");
        }
        cfg.lifecycle.detector.max_retries = v as u32;
        cfg.lifecycle.detector.enabled = true;
    }
    if cfg.lifecycle.any_enabled() && cfg.cluster_engine == ClusterEngine::Lockstep {
        // same rule as the config parser: elastic implies the event
        // engine; naming lockstep alongside it is a contradiction
        if matches!(args.flag("engine"), Some("lockstep") | Some("router")) {
            bail!(
                "--engine lockstep cannot run elastic fleets \
                 (lifecycle/autoscale/health/detector need the event engine)"
            );
        }
        cfg.cluster_engine = ClusterEngine::Event;
    }
    if let Some(v) = args.flag_u64("threads")? {
        if v < 1 {
            bail!("--threads must be >= 1");
        }
        cfg.cluster_threads = v as usize;
        if cfg.cluster_threads > 1 {
            // same rule as the [cluster] threads config key: epoch
            // workers only exist in the event engine, so naming
            // lockstep alongside them is a contradiction
            if matches!(args.flag("engine"), Some("lockstep") | Some("router")) {
                bail!(
                    "--threads > 1 applies to the event engine; \
                     use --engine event or --threads 1"
                );
            }
            cfg.cluster_engine = ClusterEngine::Event;
        }
    }

    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .generate();
    // same drain convention as cmd_serve: 300 virtual seconds past the
    // last arrival
    let report = experiments::run_fleet(
        cfg.cluster_strategy,
        &cfg.fleet(),
        workload,
        &cfg,
        secs(300.0),
    )?;

    let tasks = report.tasks();
    let fleet = Attainment::compute(&tasks);
    let lat = slice_serve::metrics::LatencySummary::compute(&tasks);
    println!(
        "cluster policy={} strategy={} replicas={} tasks={} finished={} steps={} \
         shed={} migrations={} (running {})",
        report.policy(),
        report.strategy,
        report.replicas.len(),
        fleet.n_tasks,
        fleet.n_finished,
        report.total_steps(),
        report.rejected_count(),
        report.migrations,
        report.migrated_running
    );

    let mut t = Table::new(&["fleet metric", "value"]);
    t.row(vec!["overall SLO attainment".into(), pct(fleet.slo)]);
    t.row(vec!["real-time SLO attainment".into(), pct(fleet.rt_slo)]);
    t.row(vec!["non-RT SLO attainment".into(), pct(fleet.nrt_slo)]);
    t.row(vec!["mean completion (all)".into(), secs2(fleet.mean_completion_all)]);
    t.row(vec![
        "TTFT p50 / p95 / p99".into(),
        format!(
            "{} / {} / {}",
            ms2(lat.ttft.p50_ms),
            ms2(lat.ttft.p95_ms),
            ms2(lat.ttft.p99_ms)
        ),
    ]);
    t.row(vec![
        "TPOT p50 / p95 / p99".into(),
        format!(
            "{} / {} / {}",
            ms2(lat.tpot.p50_ms),
            ms2(lat.tpot.p95_ms),
            ms2(lat.tpot.p99_ms)
        ),
    ]);
    let mem = report.fleet_memory();
    t.row(vec![
        "peak KV (fleet sum)".into(),
        format!("{:.1} MiB", mem.peak_kv_bytes as f64 / (1024.0 * 1024.0)),
    ]);
    t.row(vec![
        "swaps out / in / recompute".into(),
        format!("{} / {} / {}", mem.swap_outs, mem.swap_ins, mem.recomputes),
    ]);
    t.row(vec![
        "KV handoffs (bytes / time)".into(),
        format!(
            "{} ({:.1} MiB / {})",
            report.migrated_running,
            report.handoff_bytes as f64 / (1024.0 * 1024.0),
            ms2(report.handoff_us as f64 / 1e3)
        ),
    ]);
    if cfg.lifecycle.any_enabled() {
        let e = &report.elastic;
        t.row(vec![
            "lifecycle crash / join / leave".into(),
            format!("{} / {} / {}", e.crashes, e.joins, e.leaves),
        ]);
        t.row(vec![
            "autoscale grow / shrink".into(),
            format!("{} / {}", e.autoscale_grows, e.autoscale_shrinks),
        ]);
        t.row(vec![
            "evacuated (requeued / restarted)".into(),
            format!(
                "{} / {} ({} recompute)",
                e.evac_requeued,
                e.evac_restarted,
                secs2(e.evac_recompute_us as f64 / 1e6)
            ),
        ]);
        if cfg.lifecycle.detector.enabled {
            t.row(vec![
                "suspicions (false) / detections".into(),
                format!("{} ({}) / {}", e.suspicions, e.false_suspicions, e.detections),
            ]);
            t.row(vec![
                "limbo recovered / retries / exhausted / lost".into(),
                format!(
                    "{} / {} / {} / {}",
                    e.limbo_recovered, e.retries, e.retry_exhausted, e.limbo_lost
                ),
            ]);
        }
        t.row(vec![
            "alive replicas at horizon".into(),
            format!("{}/{}", report.alive_replicas(), report.replicas.len()),
        ]);
    }
    println!("{}", t.render());

    let mut per = Table::new(&[
        "replica", "profile", "routed", "migr in/out", "finished", "SLO attainment",
        "steps", "peak KV", "swaps", "last completion",
    ]);
    for r in &report.replicas {
        let a = Attainment::compute(&r.report.tasks);
        let last_completion = r
            .report
            .tasks
            .iter()
            .filter_map(|t| t.completion)
            .max()
            .map_or(f64::NAN, |c| c as f64 / 1e6);
        per.row(vec![
            r.replica.to_string(),
            r.profile.to_string(),
            r.routed.to_string(),
            format!("{}/{}", r.migrated_in, r.migrated_out),
            a.n_finished.to_string(),
            pct(a.slo),
            r.report.steps.to_string(),
            format!(
                "{:.1} MiB",
                r.report.memory.peak_kv_bytes as f64 / (1024.0 * 1024.0)
            ),
            format!("{}/{}", r.report.memory.swap_outs, r.report.memory.swap_ins),
            secs2(last_completion),
        ]);
    }
    println!("per-replica:\n\n{}", per.render());
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let mut cfg = ServeConfig::default();
    if let Some(v) = args.flag_u64("n-tasks")? {
        cfg.n_tasks = v as usize;
    }
    if let Some(v) = args.flag_u64("seed")? {
        cfg.seed = v;
    }

    let mut out = Json::obj();
    match which {
        "fig1" => out = out.set("fig1", experiments::fig1::run()?),
        "table2" | "fig6" => out = out.set("table2", experiments::static_mix::run(&cfg)?),
        "fig7" | "fig8" | "fig9" | "dynamic" => {
            out = out.set("dynamic", experiments::dynamic::run(&cfg)?)
        }
        "fig10" => out = out.set("fig10", experiments::ratio_sweep::run(&cfg)?),
        "fig11" => out = out.set("fig11", experiments::rate_sweep::run(&cfg)?),
        "ablation" => out = out.set("ablation", experiments::ablation::run(&cfg)?),
        "cluster" | "cluster_sweep" => {
            out = out.set("cluster_sweep", experiments::cluster_sweep::run(&cfg)?)
        }
        "hetero" | "hetero_sweep" => {
            out = out.set("hetero_sweep", experiments::hetero_sweep::run(&cfg)?)
        }
        "memory" | "memory_sweep" => {
            out = out.set("memory_sweep", experiments::memory_sweep::run(&cfg)?)
        }
        "scale" | "scale_sweep" => {
            // --tasks <n> runs a single custom size (CI smoke);
            // default: the 1k/4k/10k sweep. --replicas <n[,n,...]>
            // switches to the replica-width axis (BENCH_6.json shape).
            let tasks = match args.flag_u64("tasks")? {
                Some(n) if n >= 1 => Some(n as usize),
                Some(_) => bail!("--tasks must be >= 1"),
                None => None,
            };
            if args.flag("stream").is_some() {
                if args.flag("replicas").is_some() {
                    bail!("--stream and --replicas are different scale axes; pick one");
                }
                if args.flag("threads").is_some() {
                    bail!("--threads rides the replica-width axis; pair it with --replicas");
                }
                let sizes = match tasks {
                    Some(n) => vec![n],
                    None => experiments::scale_sweep::DEFAULT_STREAM_SIZES.to_vec(),
                };
                out = out.set(
                    "stream_sweep",
                    experiments::scale_sweep::run_streaming(&cfg, &sizes)?,
                )
            } else if let Some(spec) = args.flag("replicas") {
                let counts = spec
                    .split(',')
                    .map(|s| {
                        let n: usize = s
                            .trim()
                            .parse()
                            .with_context(|| format!("--replicas: bad count '{s}'"))?;
                        if n < 1 {
                            bail!("--replicas counts must be >= 1");
                        }
                        Ok(n)
                    })
                    .collect::<Result<Vec<_>>>()?;
                // --threads <n[,n,...]> adds the event-engine worker
                // axis: every replica width runs at every thread count
                // (reports are bit-exact across counts; only wall time
                // moves). Default is the single-threaded engine.
                let threads = match args.flag("threads") {
                    Some(spec) => spec
                        .split(',')
                        .map(|s| {
                            let n: usize = s
                                .trim()
                                .parse()
                                .with_context(|| format!("--threads: bad count '{s}'"))?;
                            if n < 1 {
                                bail!("--threads counts must be >= 1");
                            }
                            Ok(n)
                        })
                        .collect::<Result<Vec<_>>>()?,
                    None => vec![1],
                };
                let sizes = match tasks {
                    Some(n) => vec![n],
                    None => experiments::scale_sweep::DEFAULT_REPLICA_SIZES.to_vec(),
                };
                out = out.set(
                    "replica_sweep",
                    experiments::scale_sweep::run_replicas(&cfg, &counts, &sizes, &threads)?,
                )
            } else {
                if args.flag("threads").is_some() {
                    bail!("--threads rides the replica-width axis; pair it with --replicas");
                }
                let sizes = match tasks {
                    Some(n) => vec![n],
                    None => experiments::scale_sweep::DEFAULT_SIZES.to_vec(),
                };
                out = out.set("scale_sweep", experiments::scale_sweep::run(&cfg, &sizes)?)
            }
        }
        "elastic" | "elastic_sweep" => {
            // --tasks <n> runs a single custom size (CI smoke);
            // default: the 1k/10k sweep (BENCH_7.json shape).
            let sizes = match args.flag_u64("tasks")? {
                Some(n) if n >= 1 => vec![n as usize],
                Some(_) => bail!("--tasks must be >= 1"),
                None => experiments::elastic_sweep::DEFAULT_SIZES.to_vec(),
            };
            out = out.set("elastic_sweep", experiments::elastic_sweep::run(&cfg, &sizes)?)
        }
        "chaos" | "chaos_sweep" => {
            // --tasks <n> runs a single custom size (CI smoke);
            // default: the 1k/10k sweep (BENCH_10.json shape).
            let sizes = match args.flag_u64("tasks")? {
                Some(n) if n >= 1 => vec![n as usize],
                Some(_) => bail!("--tasks must be >= 1"),
                None => experiments::chaos_sweep::DEFAULT_SIZES.to_vec(),
            };
            out = out.set("chaos_sweep", experiments::chaos_sweep::run(&cfg, &sizes)?)
        }
        "all" => {
            out = out
                .set("fig1", experiments::fig1::run()?)
                .set("table2", experiments::static_mix::run(&cfg)?)
                .set("dynamic", experiments::dynamic::run(&cfg)?)
                .set("fig10", experiments::ratio_sweep::run(&cfg)?)
                .set("fig11", experiments::rate_sweep::run(&cfg)?)
                .set("ablation", experiments::ablation::run(&cfg)?)
                .set("cluster_sweep", experiments::cluster_sweep::run(&cfg)?)
                .set("hetero_sweep", experiments::hetero_sweep::run(&cfg)?)
                .set("memory_sweep", experiments::memory_sweep::run(&cfg)?);
        }
        other => bail!("unknown experiment '{other}'"),
    }

    if let Some(path) = args.flag("out") {
        std::fs::write(path, out.to_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Measure l(b) on the real engine (Fig. 1 measurement + calibration).
#[cfg(feature = "pjrt")]
fn cmd_calibrate(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
    let reps = args.flag_u64("reps")?.unwrap_or(5) as usize;
    let runtime = ModelRuntime::load(&dir)?;
    let buckets = runtime.decode_buckets();
    let mut engine = PjrtEngine::new(runtime, Sampler::Greedy, 0);

    // Build a pool of max-bucket tasks with real prompts and prefill them.
    let mut pool = slice_serve::coordinator::pool::TaskPool::new();
    let max_b = *buckets.last().unwrap();
    for i in 0..max_b as u64 {
        let mut t = slice_serve::coordinator::task::Task::new(
            i, TaskClass::TextQa, 0, 16, 64, 1.0,
        );
        t.prompt = format!("calibration prompt number {i} padding").into_bytes();
        t.prompt.truncate(16);
        t.prompt_len = t.prompt.len() as u32;
        pool.insert(t);
    }
    for i in 0..max_b as u64 {
        engine.prefill(&pool, i)?;
    }

    println!("calibrating decode latency l(b) over buckets {buckets:?}, {reps} reps\n");
    let mut t = Table::new(&["batch", "l(b) ms (median)", "throughput tok/s"]);
    let mut points = Vec::new();
    for &b in &buckets {
        let ids: Vec<u64> = (0..b as u64).collect();
        let mut samples = Vec::new();
        for _ in 0..reps {
            let o = engine.decode(&pool, &ids)?;
            samples.push(o.duration);
        }
        samples.sort_unstable();
        let med = samples[samples.len() / 2];
        points.push((b as u32, med));
        t.row(vec![
            b.to_string(),
            format!("{:.2}", med as f64 / 1e3),
            format!("{:.2}", b as f64 / (med as f64 / 1e6)),
        ]);
    }
    println!("{}", t.render());

    let model = LatencyModel::from_points(points, vec![], max_b as u32);
    println!("best-throughput batch: {}", model.best_throughput_batch());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
    let runtime = ModelRuntime::load(&dir)?;
    let d = runtime.dims();
    println!("platform: {}", runtime.platform());
    println!(
        "model: vocab={} d_model={} layers={} heads={} head_dim={} ffn={} max_seq={}",
        d.vocab, d.d_model, d.n_layers, d.n_heads, d.head_dim, d.d_ff, d.max_seq
    );
    println!(
        "kv slab: {} f32 ({} KiB) per task",
        d.kv_slab_elems(),
        d.kv_slab_elems() * 4 / 1024
    );
    println!("decode buckets: {:?}", runtime.decode_buckets());
    Ok(())
}

/// Sim-only builds keep the subcommands but point at the pjrt feature.
#[cfg(not(feature = "pjrt"))]
fn cmd_calibrate(_args: &Args) -> Result<()> {
    bail!(
        "'calibrate' needs the real engine, which is not compiled into this \
         binary; rebuild with `cargo build --release --features pjrt`"
    )
}

#[cfg(not(feature = "pjrt"))]
fn cmd_info(_args: &Args) -> Result<()> {
    bail!(
        "'info' inspects PJRT artifacts, which this binary cannot load; \
         rebuild with `cargo build --release --features pjrt`"
    )
}

/// Exit code for argument errors (matches common CLI convention).
const EXIT_USAGE: u8 = 2;

fn main() -> ExitCode {
    logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // `--help` anywhere (or a bare `help` command, or no arguments at
    // all) prints usage and exits 0; malformed arguments exit 2.
    if argv.is_empty()
        || argv.iter().any(|a| a == "--help" || a == "-h")
        || argv[0] == "help"
    {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return ExitCode::from(EXIT_USAGE);
        }
    };
    let cmd = args.positional.first().map(String::as_str);
    let result = match cmd {
        Some("serve") => cmd_serve(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("info") => cmd_info(&args),
        Some(other) => {
            eprintln!("error: unknown command '{other}'\n\n{USAGE}");
            return ExitCode::from(EXIT_USAGE);
        }
        None => {
            // flags only, no subcommand
            eprintln!("error: no command given\n\n{USAGE}");
            return ExitCode::from(EXIT_USAGE);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
