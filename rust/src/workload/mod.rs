//! Workload generation: the paper's evaluation workloads (§VI-A; the
//! class mix and SLOs are the inputs to DESIGN.md's "Scheduling
//! cycle").
//!
//! Contract: generators emit [`Task`]s sorted by arrival with dense
//! ids — exactly what `server::Server::new` and `cluster::Router::run`
//! require — and are deterministic per seed.
//!
//! Task arrivals follow a Poisson process; each task draws a class from a
//! configurable mix (real-time machine-control, voice chat, text Q&A),
//! with class-specific SLOs, utilities and prompt/output length ranges.

pub mod trace;

use crate::coordinator::task::{SloSpec, Task, TaskClass};
use crate::engine::tokenizer;
use crate::util::rng::Rng;
use crate::util::{secs, Micros, MICROS_PER_SEC};

/// Length and utility profile for one task class.
#[derive(Debug, Clone, Copy)]
pub struct ClassProfile {
    /// The task class this profile generates.
    pub class: TaskClass,
    /// Scheduling weight U_i for the class.
    pub utility: f64,
    /// Inclusive prompt-length range (tokens).
    pub prompt_range: (u32, u32),
    /// Inclusive output-length range (tokens).
    pub output_range: (u32, u32),
}

impl ClassProfile {
    /// Paper-style defaults for the simulated testbed (ChatGLM2-6B
    /// class device). Real-time tasks are short bursts (machine control
    /// commands) with 10-100x the utility of interactive tasks;
    /// voice/Q&A generate long answers (hundreds of tokens), which is
    /// what makes arrival rate 1.0 saturate the device as in §VI-C.
    pub fn default_for(class: TaskClass) -> Self {
        match class {
            TaskClass::RealTime => ClassProfile {
                class,
                utility: 100.0,
                prompt_range: (8, 24),
                // short control bursts ("machine control commands",
                // §VI-D): ~10 tokens, well inside the 1.5 s deadline at
                // the 20 tok/s SLO rate
                output_range: (6, 14),
            },
            TaskClass::Voice => ClassProfile {
                class,
                utility: 1.0,
                prompt_range: (8, 32),
                output_range: (150, 350),
            },
            TaskClass::TextQa => ClassProfile {
                class,
                utility: 2.0,
                prompt_range: (16, 48),
                output_range: (150, 350),
            },
        }
    }

    /// Context-fitted profiles for the real PJRT engine (128-token
    /// context window of the AOT-compiled tiny model): same classes and
    /// utilities, shorter generations.
    pub fn edge_for(class: TaskClass) -> Self {
        match class {
            TaskClass::RealTime => ClassProfile {
                class,
                utility: 100.0,
                prompt_range: (8, 24),
                output_range: (8, 24),
            },
            TaskClass::Voice => ClassProfile {
                class,
                utility: 1.0,
                prompt_range: (8, 32),
                output_range: (24, 64),
            },
            TaskClass::TextQa => ClassProfile {
                class,
                utility: 2.0,
                prompt_range: (16, 48),
                output_range: (16, 48),
            },
        }
    }
}

/// Full workload specification.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Poisson arrival rate, tasks per second.
    pub arrival_rate: f64,
    /// Number of tasks to generate.
    pub n_tasks: usize,
    /// (profile, weight) mix; weights need not sum to 1.
    pub mix: Vec<(ClassProfile, f64)>,
    /// RNG seed (every experiment records its seed).
    pub seed: u64,
    /// Attach synthetic prompt text (needed by the PJRT engine).
    pub with_prompt_bytes: bool,
}

impl WorkloadSpec {
    /// The paper's dynamic-experiment default: rate tasks/s with a
    /// real-time:non-real-time ratio of `rt_ratio` (paper: 0.7), the
    /// non-real-time share split evenly between voice and Q&A.
    pub fn paper_mix(arrival_rate: f64, rt_ratio: f64, n_tasks: usize, seed: u64) -> Self {
        let nrt = (1.0 - rt_ratio).max(0.0);
        WorkloadSpec {
            arrival_rate,
            n_tasks,
            mix: vec![
                (ClassProfile::default_for(TaskClass::RealTime), rt_ratio),
                (ClassProfile::default_for(TaskClass::Voice), nrt / 2.0),
                (ClassProfile::default_for(TaskClass::TextQa), nrt / 2.0),
            ],
            seed,
            with_prompt_bytes: false,
        }
    }

    /// Same mix but with context-fitted lengths and prompt bytes, for
    /// serving through the real PJRT engine (128-token context).
    pub fn edge_mix(arrival_rate: f64, rt_ratio: f64, n_tasks: usize, seed: u64) -> Self {
        let nrt = (1.0 - rt_ratio).max(0.0);
        WorkloadSpec {
            arrival_rate,
            n_tasks,
            mix: vec![
                (ClassProfile::edge_for(TaskClass::RealTime), rt_ratio),
                (ClassProfile::edge_for(TaskClass::Voice), nrt / 2.0),
                (ClassProfile::edge_for(TaskClass::TextQa), nrt / 2.0),
            ],
            seed,
            with_prompt_bytes: true,
        }
    }

    /// Generate the workload: tasks with dense ids, sorted by arrival.
    /// Exactly [`WorkloadSpec::stream`] collected — pinned by
    /// `stream_matches_generate`.
    pub fn generate(&self) -> Vec<Task> {
        self.stream().collect()
    }

    /// Pull-based generation: the same seeded task sequence as
    /// [`WorkloadSpec::generate`] (identical RNG draw order, so the
    /// tasks are bit-identical), produced one at a time so million-task
    /// traces never materialize — the constant-memory source for
    /// [`crate::cluster::Orchestrator::run_stream`].
    pub fn stream(&self) -> ArrivalStream {
        ArrivalStream {
            rng: Rng::new(self.seed),
            weights: self.mix.iter().map(|&(_, w)| w).collect(),
            mix: self.mix.clone(),
            with_prompt_bytes: self.with_prompt_bytes,
            arrival_rate: self.arrival_rate,
            remaining: self.n_tasks,
            next_id: 0,
            t: 0.0,
        }
    }
}

/// Seeded, deterministic, constant-memory workload iterator — see
/// [`WorkloadSpec::stream`]. Yields tasks with dense ids sorted by
/// arrival; memory use is O(mix), independent of the trace length.
pub struct ArrivalStream {
    rng: Rng,
    weights: Vec<f64>,
    mix: Vec<(ClassProfile, f64)>,
    with_prompt_bytes: bool,
    arrival_rate: f64,
    remaining: usize,
    next_id: u64,
    /// Current arrival time (seconds — the generator's native unit).
    t: f64,
}

impl Iterator for ArrivalStream {
    type Item = Task;

    fn next(&mut self) -> Option<Task> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let id = self.next_id;
        self.next_id += 1;
        // one task = the exact per-task draw order `generate` used:
        // gap (after the first), class, prompt len, output len, prompt
        if id > 0 {
            self.t += self.rng.exponential(self.arrival_rate);
        }
        let profile = self.mix[self.rng.weighted_index(&self.weights)].0;
        let prompt_len = self
            .rng
            .range_u64(profile.prompt_range.0 as u64, profile.prompt_range.1 as u64)
            as u32;
        let output_len = self
            .rng
            .range_u64(profile.output_range.0 as u64, profile.output_range.1 as u64)
            as u32;
        let mut task = Task::new(
            id,
            profile.class,
            secs(self.t),
            prompt_len,
            output_len,
            profile.utility,
        );
        if self.with_prompt_bytes {
            task.prompt = synthetic_prompt(profile.class, prompt_len, &mut self.rng);
        }
        Some(task)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for ArrivalStream {}

/// Build the paper's Table II static workload: all tasks arrive at t=0
/// with custom TPOT SLOs — 3x Type A (100 ms), 4x Type B (120 ms),
/// 2x Type C (250 ms), equal utility.
pub fn table2_static_workload() -> Vec<Task> {
    let mut tasks = Vec::new();
    let types: &[(Micros, usize, u32)] = &[
        (100_000, 3, 60), // (TPOT SLO, count, output tokens)
        (120_000, 4, 60),
        (250_000, 2, 60),
    ];
    let mut id = 0u64;
    for &(tpot, count, out_len) in types {
        for _ in 0..count {
            let mut t = Task::new(id, TaskClass::TextQa, 0, 16, out_len, 1.0);
            t.slo = SloSpec { ttft: 10 * MICROS_PER_SEC, tpot, deadline: None };
            tasks.push(t);
            id += 1;
        }
    }
    tasks
}

/// Text prompts for the real engine, themed per class so examples read
/// sensibly.
fn synthetic_prompt(class: TaskClass, len: u32, rng: &mut Rng) -> Vec<u8> {
    let stem = match class {
        TaskClass::RealTime => "cmd: rotate arm to ",
        TaskClass::Voice => "user says: tell me about ",
        TaskClass::TextQa => "Q: what is the status of ",
    };
    let mut bytes = tokenizer::encode(stem);
    while bytes.len() < len as usize {
        bytes.push(b'a' + (rng.range_u64(0, 25) as u8));
    }
    bytes.truncate(len as usize);
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_sorted_dense() {
        let spec = WorkloadSpec::paper_mix(1.0, 0.7, 200, 42);
        let tasks = spec.generate();
        assert_eq!(tasks.len(), 200);
        assert!(tasks.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(t.id, i as u64);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WorkloadSpec::paper_mix(1.0, 0.7, 100, 7).generate();
        let b = WorkloadSpec::paper_mix(1.0, 0.7, 100, 7).generate();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.class, y.class);
            assert_eq!(x.output_len, y.output_len);
        }
        let c = WorkloadSpec::paper_mix(1.0, 0.7, 100, 8).generate();
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival));
    }

    #[test]
    fn stream_matches_generate() {
        // the pull-based stream must reproduce the eager generator
        // bit-for-bit, prompt bytes included — `generate` is defined
        // as `stream().collect()`, and this pins the per-task RNG draw
        // order against regressions in either path
        let mut spec = WorkloadSpec::edge_mix(1.3, 0.7, 500, 42);
        spec.with_prompt_bytes = true;
        let eager = spec.generate();
        let streamed: Vec<Task> = spec.stream().collect();
        assert_eq!(eager.len(), streamed.len());
        for (a, b) in eager.iter().zip(&streamed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.class, b.class);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.utility, b.utility);
            assert_eq!(a.prompt, b.prompt);
        }
        assert_eq!(spec.stream().len(), 500, "ExactSizeIterator contract");
    }

    #[test]
    fn mix_ratio_approximately_honored() {
        let spec = WorkloadSpec::paper_mix(1.0, 0.7, 5000, 11);
        let tasks = spec.generate();
        let rt = tasks.iter().filter(|t| t.class.is_real_time()).count();
        let frac = rt as f64 / tasks.len() as f64;
        assert!((frac - 0.7).abs() < 0.03, "rt fraction {frac}");
    }

    #[test]
    fn poisson_interarrival_mean_close() {
        let spec = WorkloadSpec::paper_mix(2.0, 0.5, 20_000, 13);
        let tasks = spec.generate();
        let mean_gap = tasks.last().unwrap().arrival as f64
            / MICROS_PER_SEC as f64
            / (tasks.len() - 1) as f64;
        assert!((mean_gap - 0.5).abs() < 0.02, "mean gap {mean_gap}");
    }

    #[test]
    fn lengths_within_profile_ranges() {
        let tasks = WorkloadSpec::paper_mix(1.0, 0.7, 2000, 17).generate();
        for t in &tasks {
            let p = ClassProfile::default_for(t.class);
            assert!(t.prompt_len >= p.prompt_range.0 && t.prompt_len <= p.prompt_range.1);
            assert!(t.output_len >= p.output_range.0 && t.output_len <= p.output_range.1);
        }
    }

    #[test]
    fn edge_mix_fits_small_model_context() {
        let tasks = WorkloadSpec::edge_mix(1.0, 0.7, 500, 17).generate();
        for t in &tasks {
            // must fit the tiny AOT model's 128-token context
            assert!(t.prompt_len + t.output_len < 128);
            assert_eq!(t.prompt.len(), t.prompt_len as usize);
        }
    }

    #[test]
    fn default_mix_saturates_at_rate_one() {
        // §VI-C: arrival rate 1.0 saturates the device. Demand in
        // tokens/s must be in the same band as the device's throughput
        // capacity (~84-119 tok/s between batch 8 and the plateau).
        let tasks = WorkloadSpec::paper_mix(1.0, 0.7, 5000, 3).generate();
        let total_tokens: u64 = tasks.iter().map(|t| t.output_len as u64).sum();
        let span_s = tasks.last().unwrap().arrival as f64 / 1e6;
        let demand = total_tokens as f64 / span_s;
        assert!(
            (70.0..140.0).contains(&demand),
            "demand {demand} tok/s not at the saturation knee"
        );
    }

    #[test]
    fn prompt_bytes_generated_when_requested() {
        let mut spec = WorkloadSpec::paper_mix(1.0, 0.7, 20, 19);
        spec.with_prompt_bytes = true;
        for t in spec.generate() {
            assert_eq!(t.prompt.len(), t.prompt_len as usize);
            assert!(!t.prompt.contains(&0u8));
        }
    }

    #[test]
    fn table2_workload_matches_paper() {
        let tasks = table2_static_workload();
        assert_eq!(tasks.len(), 9);
        assert!(tasks.iter().all(|t| t.arrival == 0));
        let count_with_tpot =
            |ms: u64| tasks.iter().filter(|t| t.slo.tpot == ms * 1000).count();
        assert_eq!(count_with_tpot(100), 3);
        assert_eq!(count_with_tpot(120), 4);
        assert_eq!(count_with_tpot(250), 2);
    }
}
