//! Workload trace export/replay: freeze a generated workload to JSON so
//! experiments are byte-reproducible across machines and so real traces
//! can be substituted for the synthetic generator.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::task::{SloSpec, Task, TaskClass};
use crate::util::json::Json;

fn class_name(c: TaskClass) -> &'static str {
    match c {
        TaskClass::RealTime => "real_time",
        TaskClass::Voice => "voice",
        TaskClass::TextQa => "text_qa",
    }
}

fn class_from_name(s: &str) -> Result<TaskClass> {
    Ok(match s {
        "real_time" => TaskClass::RealTime,
        "voice" => TaskClass::Voice,
        "text_qa" => TaskClass::TextQa,
        other => anyhow::bail!("unknown task class '{other}'"),
    })
}

/// Serialize a workload (pre-run task set) to JSON.
pub fn to_json(tasks: &[Task]) -> Json {
    let arr: Vec<Json> = tasks
        .iter()
        .map(|t| {
            let mut j = Json::obj()
                .set("id", t.id)
                .set("class", class_name(t.class))
                .set("arrival_us", t.arrival)
                .set("prompt_len", t.prompt_len as u64)
                .set("output_len", t.output_len as u64)
                .set("utility", t.utility)
                .set("ttft_slo_us", t.slo.ttft)
                .set("tpot_slo_us", t.slo.tpot);
            if let Some(d) = t.slo.deadline {
                j = j.set("deadline_us", d);
            }
            if !t.prompt.is_empty() {
                j = j.set("prompt", String::from_utf8_lossy(&t.prompt).into_owned());
            }
            j
        })
        .collect();
    Json::obj().set("tasks", arr)
}

/// Parse a workload trace back into tasks (sorted by arrival, dense ids
/// reassigned in arrival order).
pub fn from_json(j: &Json) -> Result<Vec<Task>> {
    let mut tasks = Vec::new();
    for e in j.get("tasks")?.as_arr()? {
        let class = class_from_name(e.get("class")?.as_str()?)?;
        let mut t = Task::new(
            e.get("id")?.as_u64()?,
            class,
            e.get("arrival_us")?.as_u64()?,
            e.get("prompt_len")?.as_u64()? as u32,
            e.get("output_len")?.as_u64()? as u32,
            e.get("utility")?.as_f64()?,
        );
        t.slo = SloSpec {
            ttft: e.get("ttft_slo_us")?.as_u64()?,
            tpot: e.get("tpot_slo_us")?.as_u64()?,
            deadline: match e.opt("deadline_us") {
                Some(d) => Some(d.as_u64()?),
                None => None,
            },
        };
        if let Some(p) = e.opt("prompt") {
            t.prompt = p.as_str()?.as_bytes().to_vec();
        }
        tasks.push(t);
    }
    tasks.sort_by_key(|t| t.arrival);
    for (i, t) in tasks.iter_mut().enumerate() {
        t.id = i as u64;
    }
    Ok(tasks)
}

/// Write a trace file.
pub fn save(tasks: &[Task], path: &Path) -> Result<()> {
    std::fs::write(path, to_json(tasks).to_pretty())
        .with_context(|| format!("writing trace {path:?}"))
}

/// Load a trace file.
pub fn load(path: &Path) -> Result<Vec<Task>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path:?}"))?;
    from_json(&Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    #[test]
    fn round_trip_preserves_workload() {
        let mut spec = WorkloadSpec::paper_mix(1.0, 0.7, 50, 23);
        spec.with_prompt_bytes = true;
        let tasks = spec.generate();
        let j = to_json(&tasks);
        let back = from_json(&Json::parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(back.len(), tasks.len());
        for (a, b) in tasks.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.class, b.class);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.utility, b.utility);
            assert_eq!(a.slo.tpot, b.slo.tpot);
            assert_eq!(a.slo.deadline, b.slo.deadline);
            assert_eq!(a.prompt, b.prompt);
        }
    }

    #[test]
    fn rejects_unknown_class() {
        let doc = r#"{"tasks": [{"id": 0, "class": "warp", "arrival_us": 0,
            "prompt_len": 8, "output_len": 8, "utility": 1,
            "ttft_slo_us": 1, "tpot_slo_us": 1}]}"#;
        assert!(from_json(&Json::parse(doc).unwrap()).is_err());
    }
}
