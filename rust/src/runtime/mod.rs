//! Model runtime layer (DESIGN.md "Layers" — the runtime row between
//! the engine and the AOT python pipeline).
//!
//! Contract: this layer owns artifact loading and PJRT execution; it
//! knows nothing about tasks or SLOs. `engine::pjrt` adapts it to the
//! [`crate::engine::DecodeEngine`] interface.
//!
//! * [`artifact`] — the AOT artifact manifest (pure parsing, always
//!   compiled; the contract between `python/compile/aot.py` and rust).
//! * `model` (feature `pjrt`) — the PJRT bridge that compiles and
//!   executes the HLO artifacts via the `xla` crate. Gated so the
//!   default build is fully offline; build with `--features pjrt` (and
//!   the real closure in `third_party/xla`) for hardware runs.

pub mod artifact;

pub use artifact::{ArtifactEntry, Manifest, ModelDims};

#[cfg(feature = "pjrt")]
mod model;

#[cfg(feature = "pjrt")]
pub use model::{DecodeOut, ModelRuntime, PrefillOut};
