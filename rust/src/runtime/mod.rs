//! Model runtime layer.
//!
//! * [`artifact`] — the AOT artifact manifest (pure parsing, always
//!   compiled; the contract between `python/compile/aot.py` and rust).
//! * `model` (feature `pjrt`) — the PJRT bridge that compiles and
//!   executes the HLO artifacts via the `xla` crate. Gated so the
//!   default build is fully offline; build with `--features pjrt` (and
//!   the real closure in `third_party/xla`) for hardware runs.

pub mod artifact;

pub use artifact::{ArtifactEntry, Manifest, ModelDims};

#[cfg(feature = "pjrt")]
mod model;

#[cfg(feature = "pjrt")]
pub use model::{DecodeOut, ModelRuntime, PrefillOut};
