//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO **text** files
//! produced by `python/compile/aot.py` are parsed with
//! `HloModuleProto::from_text_file` (text is the id-safe interchange
//! format — see aot.py), compiled once per entry point at startup, and
//! executed from the serving hot path with zero python involvement.
//!
//! Compiled only with `--features pjrt`; the default (sim-only) build
//! never links `xla`.

use std::path::Path;

use anyhow::{Context, Result};
use xla::{ElementType, FromRawBytes, Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifact::{ArtifactEntry, Manifest, ModelDims};

/// A loaded model: PJRT client + compiled executables + weights.
pub struct ModelRuntime {
    client: PjRtClient,
    /// The parsed artifact manifest this runtime was loaded from.
    pub manifest: Manifest,
    /// Weights as literals, positional order = manifest.param_names.
    weights: Vec<Literal>,
    /// (bucket, executable), ascending bucket.
    prefill_exes: Vec<(usize, PjRtLoadedExecutable)>,
    /// (batch, executable), ascending batch.
    decode_exes: Vec<(usize, PjRtLoadedExecutable)>,
}

/// Output of a prefill call.
pub struct PrefillOut {
    /// Next-token logits, length = vocab.
    pub logits: Vec<f32>,
    /// The task's KV slab, length = dims.kv_slab_elems().
    pub kv: Vec<f32>,
}

/// Output of a decode call at batch bucket `b`.
pub struct DecodeOut {
    /// Logits for all bucket rows, row-major [b, vocab].
    pub logits: Vec<f32>,
    /// Updated KV slabs, row-major [b, slab].
    pub kv: Vec<f32>,
}

impl ModelRuntime {
    /// Load artifacts from a directory (manifest.json + *.hlo.txt +
    /// weights.npz), compiling every entry point on the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;

        // Load weights.npz in manifest order.
        let named: Vec<(String, Literal)> =
            Literal::read_npz(&manifest.weights_path, &())
                .with_context(|| format!("reading {:?}", manifest.weights_path))?;
        let mut weights = Vec::with_capacity(manifest.param_names.len());
        for name in &manifest.param_names {
            let lit = named
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, l)| l.clone())
                .with_context(|| format!("weights.npz missing '{name}'"))?;
            weights.push(lit);
        }

        let compile = |entry: &ArtifactEntry| -> Result<PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(&entry.path)
                .with_context(|| format!("parsing {:?}", entry.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {:?}", entry.path))
        };

        let mut prefill_exes = Vec::new();
        for e in &manifest.prefill {
            prefill_exes.push((e.size, compile(e)?));
        }
        let mut decode_exes = Vec::new();
        for e in &manifest.decode {
            decode_exes.push((e.size, compile(e)?));
        }

        log::info!(
            "loaded model runtime: {} prefill + {} decode executables, {} params",
            prefill_exes.len(),
            decode_exes.len(),
            weights.len()
        );
        Ok(ModelRuntime { client, manifest, weights, prefill_exes, decode_exes })
    }

    /// Model dimensions from the manifest.
    pub fn dims(&self) -> ModelDims {
        self.manifest.dims
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Run prefill for one prompt. `tokens` must already be padded to a
    /// bucket; `len` is the true prompt length.
    pub fn prefill(&self, tokens_padded: &[i32], len: i32) -> Result<PrefillOut> {
        let bucket = tokens_padded.len();
        let exe = &self
            .prefill_exes
            .iter()
            .find(|(b, _)| *b == bucket)
            .with_context(|| format!("no prefill executable for bucket {bucket}"))?
            .1;

        let tokens = Literal::vec1(tokens_padded).reshape(&[1, bucket as i64])?;
        let len_lit = Literal::scalar(len);
        let mut args: Vec<&Literal> = Vec::with_capacity(2 + self.weights.len());
        args.push(&tokens);
        args.push(&len_lit);
        args.extend(self.weights.iter());

        let result = exe.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits, kv) = result.to_tuple2()?;
        Ok(PrefillOut { logits: logits.to_vec::<f32>()?, kv: kv.to_vec::<f32>()? })
    }

    /// Run one decode iteration at batch bucket `b = lens.len()`.
    /// `kv` is the stacked slabs, row-major [b, slab]. Rows beyond the
    /// real batch should be padding with `lens = 1`.
    pub fn decode(&self, tokens: &[i32], lens: &[i32], kv: &[f32]) -> Result<DecodeOut> {
        let b = tokens.len();
        let dims = self.manifest.dims;
        let mut out = DecodeOut {
            logits: vec![0.0; b * dims.vocab],
            kv: vec![0.0; b * dims.kv_slab_elems()],
        };
        self.decode_into(tokens, lens, kv, &mut out.logits, &mut out.kv)?;
        Ok(out)
    }

    /// Allocation-free variant of [`Self::decode`]: results are copied
    /// straight from the result literal into caller-owned scratch
    /// (`logits_out`: [b, vocab], `kv_out`: [b, slab]) — the serving hot
    /// path reuses these buffers across steps (EXPERIMENTS.md §Perf
    /// iteration 2).
    pub fn decode_into(
        &self,
        tokens: &[i32],
        lens: &[i32],
        kv: &[f32],
        logits_out: &mut [f32],
        kv_out: &mut [f32],
    ) -> Result<()> {
        let b = tokens.len();
        assert_eq!(lens.len(), b);
        let dims = self.manifest.dims;
        assert_eq!(kv.len(), b * dims.kv_slab_elems(), "kv stack size mismatch");
        assert_eq!(logits_out.len(), b * dims.vocab);
        assert_eq!(kv_out.len(), kv.len());
        let exe = &self
            .decode_exes
            .iter()
            .find(|(bb, _)| *bb == b)
            .with_context(|| format!("no decode executable for batch {b}"))?
            .1;

        let tokens_lit = Literal::vec1(tokens);
        let lens_lit = Literal::vec1(lens);
        let kv_dims = dims.kv_dims(b);
        let kv_bytes = unsafe {
            std::slice::from_raw_parts(kv.as_ptr() as *const u8, kv.len() * 4)
        };
        let kv_lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &kv_dims, kv_bytes)?;

        let mut args: Vec<&Literal> = Vec::with_capacity(3 + self.weights.len());
        args.push(&tokens_lit);
        args.push(&lens_lit);
        args.push(&kv_lit);
        args.extend(self.weights.iter());

        let result = exe.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits, kv_new) = result.to_tuple2()?;
        logits.copy_raw_to(logits_out)?;
        kv_new.copy_raw_to(kv_out)?;
        Ok(())
    }

    /// Available decode batch buckets (ascending).
    pub fn decode_buckets(&self) -> Vec<usize> {
        self.decode_exes.iter().map(|&(b, _)| b).collect()
    }
}
