//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! and the rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model dimensions recorded by the AOT pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    /// Vocabulary size (256 for the byte-level model).
    pub vocab: usize,
    /// Embedding width.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Context window (prompt + output).
    pub max_seq: usize,
}

impl ModelDims {
    /// Elements in one task's KV slab: [L, 2, H, S, hd].
    pub fn kv_slab_elems(&self) -> usize {
        self.n_layers * 2 * self.n_heads * self.max_seq * self.head_dim
    }

    /// KV slab dims for a batch of `b` tasks.
    pub fn kv_dims(&self, b: usize) -> Vec<usize> {
        vec![b, self.n_layers, 2, self.n_heads, self.max_seq, self.head_dim]
    }
}

/// One compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Prompt bucket (prefill) or batch size (decode).
    pub size: usize,
    /// Path to the HLO text artifact.
    pub path: PathBuf,
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model dimensions.
    pub dims: ModelDims,
    /// Weight-initialization seed recorded by the AOT pipeline.
    pub seed: u64,
    /// Parameter names, in weights-file order.
    pub param_names: Vec<String>,
    /// Path to the weights .npz.
    pub weights_path: PathBuf,
    /// Prefill entries, ascending bucket.
    pub prefill: Vec<ArtifactEntry>,
    /// Decode entries, ascending batch size.
    pub decode: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; artifact paths resolve relative to `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let m = j.get("model")?;
        let dims = ModelDims {
            vocab: m.get("vocab")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            head_dim: m.get("head_dim")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            max_seq: m.get("max_seq")?.as_usize()?,
        };
        let param_names = j
            .get("param_names")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let entries = |key: &str, size_key: &str| -> Result<Vec<ArtifactEntry>> {
            let mut out = Vec::new();
            for e in j.get(key)?.as_arr()? {
                out.push(ArtifactEntry {
                    size: e.get(size_key)?.as_usize()?,
                    path: dir.join(e.get("path")?.as_str()?),
                });
            }
            if out.is_empty() {
                bail!("manifest has no {key} entries");
            }
            if !out.windows(2).all(|w| w[0].size < w[1].size) {
                bail!("manifest {key} entries not ascending");
            }
            Ok(out)
        };
        Ok(Manifest {
            dims,
            seed: j.get("seed")?.as_u64()?,
            param_names,
            weights_path: dir.join(j.get("weights")?.as_str()?),
            prefill: entries("prefill", "bucket")?,
            decode: entries("decode", "batch")?,
        })
    }

    /// Smallest prefill bucket that fits a prompt of `len` tokens.
    pub fn prefill_bucket(&self, len: usize) -> Result<usize> {
        self.prefill
            .iter()
            .map(|e| e.size)
            .find(|&b| b >= len)
            .with_context(|| format!("prompt of {len} tokens exceeds largest bucket"))
    }

    /// Smallest decode batch bucket that fits `n` tasks.
    pub fn decode_bucket(&self, n: usize) -> Result<usize> {
        self.decode
            .iter()
            .map(|e| e.size)
            .find(|&b| b >= n)
            .with_context(|| format!("batch of {n} exceeds largest decode bucket"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": {"vocab": 256, "d_model": 128, "n_layers": 4, "n_heads": 4,
                  "head_dim": 32, "d_ff": 512, "max_seq": 128},
        "seed": 42,
        "param_names": ["tok_emb", "pos_emb"],
        "weights": "weights.npz",
        "prefill": [{"bucket": 16, "path": "prefill_p16.hlo.txt"},
                    {"bucket": 64, "path": "prefill_p64.hlo.txt"}],
        "decode": [{"batch": 1, "path": "decode_b1.hlo.txt"},
                   {"batch": 4, "path": "decode_b4.hlo.txt"},
                   {"batch": 16, "path": "decode_b16.hlo.txt"}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.dims.vocab, 256);
        assert_eq!(m.dims.kv_slab_elems(), 4 * 2 * 4 * 128 * 32);
        assert_eq!(m.dims.kv_dims(2), vec![2, 4, 2, 4, 128, 32]);
        assert_eq!(m.seed, 42);
        assert_eq!(m.weights_path, Path::new("/a/weights.npz"));
        assert_eq!(m.prefill.len(), 2);
        assert_eq!(m.decode.len(), 3);
        assert_eq!(m.decode[2].path, Path::new("/a/decode_b16.hlo.txt"));
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.prefill_bucket(10).unwrap(), 16);
        assert_eq!(m.prefill_bucket(16).unwrap(), 16);
        assert_eq!(m.prefill_bucket(17).unwrap(), 64);
        assert!(m.prefill_bucket(65).is_err());
        assert_eq!(m.decode_bucket(1).unwrap(), 1);
        assert_eq!(m.decode_bucket(3).unwrap(), 4);
        assert_eq!(m.decode_bucket(16).unwrap(), 16);
        assert!(m.decode_bucket(17).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}", Path::new("/a")).is_err());
        let no_decode = SAMPLE.replace("\"decode\"", "\"dec0de\"");
        assert!(Manifest::parse(&no_decode, Path::new("/a")).is_err());
    }
}
