//! Report rendering: text tables for the CLI and JSON export for
//! downstream plotting, shared by every experiment harness.

use crate::engine::memory::MemoryStats;
use crate::util::json::Json;

use super::{Attainment, LatencySummary, Percentiles};

/// A simple fixed-width text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render the aligned fixed-width text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Percentage formatting used throughout reports.
pub fn pct(x: f64) -> String {
    if x.is_nan() {
        "n/a".to_string()
    } else {
        format!("{:.2}%", 100.0 * x)
    }
}

/// Seconds with 2 decimals.
pub fn secs2(x: f64) -> String {
    if x.is_nan() {
        "n/a".to_string()
    } else {
        format!("{x:.2}s")
    }
}

/// Milliseconds with 2 decimals.
pub fn ms2(x: f64) -> String {
    if x.is_nan() {
        "n/a".to_string()
    } else {
        format!("{x:.2}ms")
    }
}

/// NaN-safe JSON number (NaN has no JSON encoding; it maps to null).
pub fn nan_null(x: f64) -> Json {
    if x.is_nan() {
        Json::Null
    } else {
        Json::Num(x)
    }
}

/// JSON encoding of an [`Attainment`] (NaN mapped to null).
pub fn attainment_json(a: &Attainment) -> Json {
    Json::obj()
        .set("n_tasks", a.n_tasks)
        .set("n_finished", a.n_finished)
        .set("slo", nan_null(a.slo))
        .set("rt_slo", nan_null(a.rt_slo))
        .set("rt_count", a.rt_count)
        .set("nrt_slo", nan_null(a.nrt_slo))
        .set("nrt_count", a.nrt_count)
        .set("nrt_ttft", nan_null(a.nrt_ttft))
        .set("nrt_tpot", nan_null(a.nrt_tpot))
        .set("mean_completion_all", nan_null(a.mean_completion_all))
        .set("mean_completion_rt", nan_null(a.mean_completion_rt))
        .set("mean_completion_nrt", nan_null(a.mean_completion_nrt))
}

/// JSON encoding of a [`Percentiles`] distribution (NaN mapped to null).
pub fn percentiles_json(p: &Percentiles) -> Json {
    Json::obj()
        .set("n", p.n)
        .set("mean_ms", nan_null(p.mean_ms))
        .set("p50_ms", nan_null(p.p50_ms))
        .set("p95_ms", nan_null(p.p95_ms))
        .set("p99_ms", nan_null(p.p99_ms))
}

/// JSON encoding of a [`LatencySummary`].
pub fn latency_summary_json(s: &LatencySummary) -> Json {
    Json::obj()
        .set("ttft", percentiles_json(&s.ttft))
        .set("tpot", percentiles_json(&s.tpot))
}

/// JSON encoding of a [`MemoryStats`] (KV peak + transition counters).
pub fn memory_stats_json(m: &MemoryStats) -> Json {
    Json::obj()
        .set("peak_kv_bytes", m.peak_kv_bytes)
        .set("swap_outs", m.swap_outs)
        .set("swap_ins", m.swap_ins)
        .set("recomputes", m.recomputes)
        .set("handoff_restores", m.handoff_restores)
        .set("swap_delay_us", m.swap_delay)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "22222".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("alpha"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.8333), "83.33%");
        assert_eq!(pct(f64::NAN), "n/a");
        assert_eq!(secs2(1.5), "1.50s");
        assert_eq!(ms2(128.59), "128.59ms");
    }
}
