//! Metrics: SLO attainment accounting (paper §VI-A "Metrics") and
//! report construction for every table/figure; fleet-level percentile
//! summaries for cluster mode (DESIGN.md "Cluster layer").
//!
//! Contract: metrics are pure functions over finished [`Task`] records
//! — nothing here mutates scheduling state, so every experiment and
//! the cluster aggregator share one measurement pipeline.
//!
//! Attainment definitions follow the paper exactly:
//!   * real-time task SLO met  ⇔ completed before its deadline;
//!   * non-real-time SLO met   ⇔ TTFT SLO **and** TPOT SLO both met;
//!   * unfinished tasks count as violations;
//!   * shed tasks (admission-rejected or dropped mid-run for memory)
//!     count as violations and are never "finished" — their partial
//!     latency records are excluded from every distribution.

pub mod report;

use crate::coordinator::task::Task;
use crate::util::stats::Samples;

/// Attainment and latency summary for a set of tasks.
#[derive(Debug, Clone)]
pub struct Attainment {
    /// Tasks in the evaluated set.
    pub n_tasks: usize,
    /// Tasks that finished (served to completion) before the horizon —
    /// shed tasks are terminal but never count here.
    pub n_finished: usize,
    /// Overall SLO attainment in [0,1].
    pub slo: f64,
    /// Real-time subset: deadline attainment.
    pub rt_slo: f64,
    /// Real-time tasks in the set.
    pub rt_count: usize,
    /// Non-real-time subset: combined TTFT+TPOT attainment.
    pub nrt_slo: f64,
    /// Non-real-time tasks in the set.
    pub nrt_count: usize,
    /// Non-real-time TTFT-only attainment (Fig. 8).
    pub nrt_ttft: f64,
    /// Non-real-time TPOT-only attainment (Fig. 8).
    pub nrt_tpot: f64,
    /// Mean completion time (s) over finished tasks, by group.
    pub mean_completion_all: f64,
    /// Mean completion time (s), real-time subset.
    pub mean_completion_rt: f64,
    /// Mean completion time (s), non-real-time subset.
    pub mean_completion_nrt: f64,
}

fn frac(num: usize, den: usize) -> f64 {
    if den == 0 {
        f64::NAN
    } else {
        num as f64 / den as f64
    }
}

fn mean_completion<'a>(tasks: impl Iterator<Item = &'a Task>) -> f64 {
    let mut s = Samples::new();
    for t in tasks {
        if let Some(c) = t.completion_time() {
            s.push(c as f64 / 1e6);
        }
    }
    s.mean()
}

impl Attainment {
    /// Compute attainment over a finished run's task set.
    pub fn compute(tasks: &[Task]) -> Self {
        let rt: Vec<&Task> = tasks.iter().filter(|t| t.class.is_real_time()).collect();
        let nrt: Vec<&Task> = tasks.iter().filter(|t| !t.class.is_real_time()).collect();

        let met = tasks.iter().filter(|t| t.slo_met()).count();
        let rt_met = rt.iter().filter(|t| t.slo_met()).count();
        let nrt_met = nrt.iter().filter(|t| t.slo_met()).count();
        let nrt_ttft_met = nrt
            .iter()
            .filter(|t| t.is_finished() && !t.shed && t.ttft_met())
            .count();
        let nrt_tpot_met = nrt
            .iter()
            .filter(|t| t.is_finished() && !t.shed && t.tpot_met())
            .count();

        Attainment {
            n_tasks: tasks.len(),
            n_finished: tasks.iter().filter(|t| t.is_finished() && !t.shed).count(),
            slo: frac(met, tasks.len()),
            rt_slo: frac(rt_met, rt.len()),
            rt_count: rt.len(),
            nrt_slo: frac(nrt_met, nrt.len()),
            nrt_count: nrt.len(),
            nrt_ttft: frac(nrt_ttft_met, nrt.len()),
            nrt_tpot: frac(nrt_tpot_met, nrt.len()),
            mean_completion_all: mean_completion(tasks.iter()),
            mean_completion_rt: mean_completion(rt.into_iter()),
            mean_completion_nrt: mean_completion(nrt.into_iter()),
        }
    }
}

/// Distribution summary in milliseconds: mean plus p50/p95/p99. All
/// fields are NaN when the sample set is empty (rendered as "n/a").
#[derive(Debug, Clone, Copy)]
pub struct Percentiles {
    /// Number of samples summarized.
    pub n: usize,
    /// Arithmetic mean (ms).
    pub mean_ms: f64,
    /// Median (ms).
    pub p50_ms: f64,
    /// 95th percentile (ms).
    pub p95_ms: f64,
    /// 99th percentile (ms).
    pub p99_ms: f64,
}

impl Percentiles {
    /// Summarize an iterator of durations in micros.
    pub fn compute(values_us: impl Iterator<Item = crate::util::Micros>) -> Self {
        let mut s = Samples::new();
        for v in values_us {
            s.push(v as f64 / 1e3);
        }
        Percentiles {
            n: s.len(),
            mean_ms: s.mean(),
            p50_ms: s.p50(),
            p95_ms: s.p95(),
            p99_ms: s.p99(),
        }
    }
}

/// TTFT/TPOT distributions over the finished tasks of a run — the
/// per-replica and fleet-wide latency report of cluster mode.
#[derive(Debug, Clone, Copy)]
pub struct LatencySummary {
    /// Time-to-first-token distribution (ms).
    pub ttft: Percentiles,
    /// Average time-per-output-token distribution (ms).
    pub tpot: Percentiles,
}

impl LatencySummary {
    /// Compute over the served-to-completion tasks in `tasks`
    /// (unfinished and shed tasks have no complete latency record;
    /// attainment already counts them as violations).
    pub fn compute(tasks: &[Task]) -> Self {
        let finished = || tasks.iter().filter(|t| t.is_finished() && !t.shed);
        LatencySummary {
            ttft: Percentiles::compute(finished().filter_map(|t| t.ttft())),
            tpot: Percentiles::compute(finished().filter_map(|t| t.avg_tpot())),
        }
    }
}

/// Per-group TPOT summary (Table II / Fig. 6): mean measured TPOT and
/// the implied decoding rate for a named group of tasks.
#[derive(Debug, Clone)]
pub struct TpotSummary {
    /// Group label ("Task A", "voice", ...).
    pub label: String,
    /// Tasks in the group.
    pub n_tasks: usize,
    /// The group's TPOT SLO (ms).
    pub tpot_slo_ms: f64,
    /// Mean measured TPOT (ms).
    pub mean_tpot_ms: f64,
    /// Implied decoding rate 1000 / mean TPOT (tokens/s).
    pub mean_rate: f64,
    /// True iff every task in the group finished and met its TPOT SLO.
    pub all_tpot_met: bool,
}

impl TpotSummary {
    /// Summarize the measured TPOT of a named task group.
    pub fn compute(label: &str, tasks: &[&Task]) -> Self {
        let mut s = Samples::new();
        for t in tasks {
            if let Some(tp) = t.avg_tpot() {
                s.push(tp as f64 / 1e3);
            }
        }
        let mean_tpot_ms = s.mean();
        TpotSummary {
            label: label.to_string(),
            n_tasks: tasks.len(),
            tpot_slo_ms: tasks.first().map_or(f64::NAN, |t| t.slo.tpot as f64 / 1e3),
            mean_tpot_ms,
            mean_rate: if mean_tpot_ms > 0.0 { 1000.0 / mean_tpot_ms } else { f64::NAN },
            all_tpot_met: tasks.iter().all(|t| t.is_finished() && t.tpot_met()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskClass};
    use crate::util::ms;

    fn finished_rt(id: u64, completion_ms: f64) -> Task {
        let mut t = Task::new(id, TaskClass::RealTime, 0, 16, 2, 100.0);
        t.on_token(ms(completion_ms / 2.0));
        t.on_token(ms(completion_ms));
        t
    }

    fn finished_voice(id: u64, ttft_ms: f64, tpot_ms: f64) -> Task {
        let mut t = Task::new(id, TaskClass::Voice, 0, 16, 5, 1.0);
        for i in 0..5u64 {
            t.on_token(ms(ttft_ms) + i * ms(tpot_ms));
        }
        t
    }

    #[test]
    fn attainment_groups_and_rates() {
        let tasks = vec![
            finished_rt(0, 1000.0),           // meets 1.5s deadline
            finished_rt(1, 2000.0),           // misses
            finished_voice(2, 500.0, 100.0),  // meets both
            finished_voice(3, 1500.0, 100.0), // TTFT violation
        ];
        let a = Attainment::compute(&tasks);
        assert_eq!(a.n_tasks, 4);
        assert_eq!(a.n_finished, 4);
        assert_eq!(a.rt_count, 2);
        assert_eq!(a.nrt_count, 2);
        assert!((a.slo - 0.5).abs() < 1e-12);
        assert!((a.rt_slo - 0.5).abs() < 1e-12);
        assert!((a.nrt_slo - 0.5).abs() < 1e-12);
        assert!((a.nrt_ttft - 0.5).abs() < 1e-12);
        assert!((a.nrt_tpot - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unfinished_counts_as_violation() {
        let mut unfinished = Task::new(0, TaskClass::Voice, 0, 16, 50, 1.0);
        unfinished.on_token(ms(100.0));
        let a = Attainment::compute(&[unfinished]);
        assert_eq!(a.n_finished, 0);
        assert_eq!(a.slo, 0.0);
    }

    #[test]
    fn shed_tasks_are_violations_not_finished() {
        // a shed task is in Finished state (terminal) but must never
        // count as served: not in n_finished, not in any latency
        // distribution, always an SLO violation
        let mut dropped = finished_voice(4, 500.0, 100.0);
        dropped.shed = true;
        let tasks = vec![finished_voice(0, 500.0, 100.0), dropped];
        let a = Attainment::compute(&tasks);
        assert_eq!(a.n_tasks, 2);
        assert_eq!(a.n_finished, 1, "shed is terminal but never served");
        assert!((a.slo - 0.5).abs() < 1e-12);
        assert!((a.nrt_slo - 0.5).abs() < 1e-12);
        assert!((a.nrt_ttft - 0.5).abs() < 1e-12, "shed out of TTFT numerator");
        let s = LatencySummary::compute(&tasks);
        assert_eq!(s.ttft.n, 1, "shed partial record excluded");
        assert_eq!(s.tpot.n, 1);
    }

    #[test]
    fn empty_groups_are_nan() {
        let tasks = vec![finished_rt(0, 1000.0)];
        let a = Attainment::compute(&tasks);
        assert!(a.nrt_slo.is_nan());
        assert!((a.rt_slo - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tpot_summary_mean_and_rate() {
        let t1 = finished_voice(0, 100.0, 100.0);
        let t2 = finished_voice(1, 100.0, 120.0);
        let s = TpotSummary::compute("voice", &[&t1, &t2]);
        assert_eq!(s.n_tasks, 2);
        assert!((s.mean_tpot_ms - 110.0).abs() < 1e-9);
        assert!((s.mean_rate - 1000.0 / 110.0).abs() < 1e-9);
        assert!(s.all_tpot_met);
    }

    #[test]
    fn mean_completion_in_seconds() {
        let tasks = vec![finished_rt(0, 1000.0), finished_rt(1, 2000.0)];
        let a = Attainment::compute(&tasks);
        assert!((a.mean_completion_all - 1.5).abs() < 1e-9);
    }

    #[test]
    fn latency_summary_over_finished_tasks() {
        let mut unfinished = Task::new(2, TaskClass::Voice, 0, 16, 50, 1.0);
        unfinished.on_token(ms(100.0));
        let tasks = vec![
            finished_voice(0, 500.0, 100.0),
            finished_voice(1, 700.0, 120.0),
            unfinished,
        ];
        let s = LatencySummary::compute(&tasks);
        assert_eq!(s.ttft.n, 2, "unfinished task excluded");
        assert!((s.ttft.mean_ms - 600.0).abs() < 1e-9);
        assert!((s.ttft.p50_ms - 600.0).abs() < 1e-9);
        assert!((s.tpot.mean_ms - 110.0).abs() < 1e-9);
        assert!(s.ttft.p99_ms >= s.ttft.p50_ms);
    }

    #[test]
    fn percentiles_empty_is_nan() {
        let p = Percentiles::compute(std::iter::empty());
        assert_eq!(p.n, 0);
        assert!(p.mean_ms.is_nan() && p.p50_ms.is_nan() && p.p99_ms.is_nan());
    }
}
