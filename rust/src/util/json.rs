//! Minimal JSON parser + writer (serde_json is unavailable offline).
//!
//! Used for the AOT `artifacts/manifest.json`, experiment result export,
//! and config files. Supports the full JSON grammar minus exotic number
//! forms; numbers parse to f64 with integer accessors.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (integers are exact up to 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// Required object lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    /// Optional object lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// This value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    /// This value as a non-negative integer.
    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as u64)
    }

    /// This value as a usize.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    /// This value as a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    /// This value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// This value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    // -- builders ----------------------------------------------------------

    /// An empty object (builder root).
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder: set `key` on an object, returning the object.
    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.pos)
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected character at byte {}", self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // surrogate pairs unsupported (not needed for our files)
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("unknown escape at byte {}", self.pos),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit()
                || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let doc = r#"{
            "model": {"vocab": 256, "max_seq": 128},
            "decode": [{"batch": 1, "path": "decode_b1.hlo.txt"}],
            "ok": true, "pi": 3.25, "none": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("model").unwrap().get("vocab").unwrap().as_u64().unwrap(), 256);
        assert_eq!(
            j.get("decode").unwrap().as_arr().unwrap()[0]
                .get("path")
                .unwrap()
                .as_str()
                .unwrap(),
            "decode_b1.hlo.txt"
        );
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(j.get("pi").unwrap().as_f64().unwrap(), 3.25);
        assert_eq!(*j.get("none").unwrap(), Json::Null);
    }

    #[test]
    fn round_trip_compact_and_pretty() {
        let v = Json::obj()
            .set("a", 1u64)
            .set("b", vec![Json::from(1u64), Json::from("x"), Json::Null])
            .set("s", "he\"llo\n");
        for text in [v.to_string(), v.to_pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
    }

    #[test]
    fn escapes_round_trip() {
        let s = "tab\t newline\n quote\" back\\ unicode\u{1}";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn negative_and_float_numbers() {
        let j = Json::parse("[-1, -2.5, 1e3, 0.125]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -1.0);
        assert_eq!(a[1].as_f64().unwrap(), -2.5);
        assert_eq!(a[2].as_f64().unwrap(), 1000.0);
        assert_eq!(a[3].as_f64().unwrap(), 0.125);
        assert!(a[1].as_u64().is_err());
    }
}
