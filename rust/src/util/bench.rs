//! Micro-benchmark harness (criterion is unavailable offline): warmup,
//! adaptive iteration count, mean/p50/p99 reporting. Used by the
//! `benches/` binaries (`harness = false`).

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::Samples;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark case name.
    pub name: String,
    /// Timed iterations executed.
    pub iters: u64,
    /// Mean per-call duration (ns).
    pub mean_ns: f64,
    /// Median per-call duration (ns).
    pub p50_ns: f64,
    /// 99th-percentile per-call duration (ns).
    pub p99_ns: f64,
}

impl BenchResult {
    /// One aligned report line (see [`report_header`]).
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters
        )
    }
}

/// Human-readable duration from nanoseconds (ns/us/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Header matching [`BenchResult::report_line`].
pub fn report_header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p99"
    )
}

/// Run `f` repeatedly for ~`budget` after warmup and report timings.
/// `f` should return something; it is black_box'ed to keep the work.
pub fn bench<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup: ~10% of budget, at least one call
    let warmup_end = Instant::now() + budget / 10;
    let mut warm_iters: u64 = 0;
    loop {
        black_box(f());
        warm_iters += 1;
        if Instant::now() >= warmup_end {
            break;
        }
    }

    // batched timing so very fast ops are measurable
    let per_call_est = (budget.as_nanos() as f64 / 10.0) / warm_iters.max(1) as f64;
    let batch = if per_call_est < 1_000.0 {
        (1_000.0 / per_call_est.max(1.0)).ceil() as u64
    } else {
        1
    };

    let mut samples = Samples::new();
    let mut iters = 0u64;
    let end = Instant::now() + budget;
    while Instant::now() < end {
        let t0 = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(dt);
        iters += batch;
    }

    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        p50_ns: samples.p50(),
        p99_ns: samples.p99(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleepy_op() {
        let r = bench("sleep50us", Duration::from_millis(50), || {
            std::thread::sleep(Duration::from_micros(50));
        });
        assert!(r.mean_ns > 40_000.0, "mean {}", r.mean_ns);
        assert!(r.iters > 0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1_500.0), "1.50us");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.00s");
    }
}
