//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is unavailable offline, so the workload generator and
//! property tests use this self-contained xoshiro256++ implementation
//! (Blackman & Vigna) seeded via SplitMix64. Determinism matters: every
//! experiment in EXPERIMENTS.md is reproducible from its seed.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/consecutive seeds give
    /// well-distributed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: lo > hi");
        let span = hi - lo + 1;
        // Lemire-style rejection-free-enough reduction; span << 2^64 so
        // modulo bias is negligible for workload generation, but use
        // rejection sampling anyway to keep property tests exact.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Uniform integer in [lo, hi] (inclusive), as usize.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Pick a weighted index: weights need not sum to 1. Panics on empty
    /// or all-zero weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_is_inclusive_and_bounded() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 7);
            assert!((3..=7).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 7;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(11);
        let lambda = 2.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted_index(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_deterministic_per_seed() {
        let shuffled = |seed: u64| {
            let mut xs: Vec<u32> = (0..32).collect();
            Rng::new(seed).shuffle(&mut xs);
            xs
        };
        assert_eq!(shuffled(23), shuffled(23));
        assert_ne!(shuffled(23), shuffled(24));
    }

    #[test]
    fn clone_preserves_stream() {
        let mut a = Rng::new(99);
        let _ = a.next_u64(); // advance past the seed state
        let mut b = a.clone();
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_single_point_is_constant() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(r.range_u64(7, 7), 7);
            assert_eq!(r.range_usize(0, 0), 0);
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(31);
        for _ in 0..1_000 {
            assert!(!r.chance(0.0));
            assert!(r.chance(1.0));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        // distribution sanity: uniform [0,1) sample mean ~ 0.5
        let mut r = Rng::new(37);
        let n = 100_000;
        let mean = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }
}
