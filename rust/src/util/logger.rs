//! Tiny `log` facade backend (tracing-subscriber is unavailable offline).
//!
//! Level comes from `SLICE_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr so stdout stays machine-parseable for the
//! experiment harnesses.

use std::io::Write;
use std::sync::Once;

use log::{Level, LevelFilter, Log, Metadata, Record};

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{tag} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();
static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("SLICE_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
