//! Summary statistics used by metrics reporting and the bench harness.

/// Online-collected sample set with quantile support.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    /// An empty sample set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Number of samples recorded.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Smallest sample (+inf when empty).
    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (-inf when empty).
    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub fn stddev(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64;
        var.sqrt()
    }

    /// Quantile by linear interpolation on sorted samples, q in [0,1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = pos - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    /// Median.
    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    /// 95th percentile.
    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th percentile.
    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_min_max() {
        let mut s = Samples::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn quantiles_interpolate() {
        let mut s = Samples::new();
        for x in 0..101 {
            s.push(x as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p95(), 95.0);
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), 100.0);
    }

    #[test]
    fn empty_quantile_is_nan() {
        let mut s = Samples::new();
        assert!(s.p50().is_nan());
        assert!(s.mean().is_nan());
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let mut s = Samples::new();
        for _ in 0..10 {
            s.push(3.0);
        }
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn single_element_is_every_quantile() {
        let mut s = Samples::new();
        s.push(42.0);
        assert_eq!(s.len(), 1);
        assert!(!s.is_empty());
        assert_eq!(s.mean(), 42.0);
        assert_eq!(s.stddev(), 0.0);
        for q in [0.0, 0.25, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(s.quantile(q), 42.0, "q={q}");
        }
        assert_eq!(s.min(), 42.0);
        assert_eq!(s.max(), 42.0);
    }

    #[test]
    fn out_of_range_quantiles_clamp() {
        let mut s = Samples::new();
        for x in [1.0, 2.0, 3.0] {
            s.push(x);
        }
        assert_eq!(s.quantile(-0.5), 1.0);
        assert_eq!(s.quantile(1.5), 3.0);
    }

    #[test]
    fn quantile_stays_correct_after_more_pushes() {
        // pushing after a quantile call must re-sort, not reuse stale order
        let mut s = Samples::new();
        s.push(10.0);
        s.push(20.0);
        assert_eq!(s.p50(), 15.0);
        s.push(0.0);
        assert_eq!(s.p50(), 10.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn stddev_matches_known_sample() {
        // sample stddev of [2,4,4,4,5,5,7,9] (n-1 denominator) = 2.138...
        let mut s = Samples::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.stddev() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }
}
