//! Small self-contained substrates: deterministic RNG, JSON, summary
//! statistics, micro-bench timing helpers and a log facade backend
//! (DESIGN.md "Dependency policy" — why these are in-tree).
//!
//! Dependency policy: the default build is fully offline. The only
//! dependencies are the vendored `anyhow`/`log` **API shims** under
//! `vendor/` (kept so source files read like standard rust and can move
//! to the real crates unchanged), plus the optional `xla` PJRT closure
//! at `third_party/xla` behind the `pjrt` cargo feature (an API stub by
//! default — see third_party/xla/README.md). Everything else a serving
//! stack normally pulls from crates.io (rand, serde_json, toml,
//! criterion, proptest) is reimplemented minimally in this module tree
//! or `config::toml`.

pub mod bench;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;

/// Microseconds since an arbitrary epoch — the time unit used throughout
/// the scheduler and simulator (integer math, no float drift).
pub type Micros = u64;

/// Microseconds per millisecond.
pub const MICROS_PER_MS: u64 = 1_000;
/// Microseconds per second.
pub const MICROS_PER_SEC: u64 = 1_000_000;

/// Convert milliseconds (possibly fractional) to [`Micros`].
pub fn ms(v: f64) -> Micros {
    (v * 1_000.0).round() as Micros
}

/// Convert seconds (possibly fractional) to [`Micros`].
pub fn secs(v: f64) -> Micros {
    (v * 1_000_000.0).round() as Micros
}

/// [`Micros`] to fractional milliseconds (for reporting).
pub fn to_ms(v: Micros) -> f64 {
    v as f64 / 1_000.0
}

/// [`Micros`] to fractional seconds (for reporting).
pub fn to_secs(v: Micros) -> f64 {
    v as f64 / 1_000_000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions_round_trip() {
        assert_eq!(ms(1.0), 1_000);
        assert_eq!(ms(128.59), 128_590);
        assert_eq!(secs(1.5), 1_500_000);
        assert!((to_ms(128_590) - 128.59).abs() < 1e-9);
        assert!((to_secs(1_500_000) - 1.5).abs() < 1e-12);
    }
}
